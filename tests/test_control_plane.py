"""Out-of-process anchor control plane (repro.control_plane).

Four contracts under test:

* **Parity** — a ``ProcessShardedRegistry`` driven over the pickled
  message path produces composed snapshots bit-identical to the
  in-process ``ShardedAnchorRegistry`` twin over the same operation
  sequence, at S ∈ {1, 4, 16} and under both placement modes.
* **Determinism** — the RPC timeout / retry / backoff state machine runs
  on an injectable clock: tests assert the exact backoff schedule and
  the exact number of deadline expiries, with zero wall-clock sleeps.
* **Degradation** — an unresponsive shard never blocks the window
  cadence: its slice serves stale (and trust-discounted via
  ``routing_view``), writes to it are dropped and counted, and recovery
  is a single probe per sync.
* **Chaos** — a SIGKILLed real worker process is detected, its state
  restored (composer mirror or ``ReplicatedAnchor`` ledger) and the
  respawned worker re-adopts through the delta protocol's full-sync
  fallback, with snapshot parity re-established.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import GTRACConfig
from repro.control_plane import (
    FakeClock,
    LoopbackTransport,
    ProcessShardedRegistry,
    RpcChannel,
    RpcPolicy,
    RpcRemoteError,
    RpcTimeout,
    ShardHost,
    WorkerDown,
)
from repro.core.failover import ReplicatedAnchor
from repro.core.sharding import (
    ShardedAnchorRegistry,
    make_registry,
    stable_peer_hash,
    stable_peer_hash_vec,
)
from repro.core.types import ExecReport, HopReport

SNAP_COLS = ("peer_ids", "layer_start", "layer_end", "trust",
             "latency_ms", "alive")


def assert_tables_equal(a, b, msg=""):
    for col in SNAP_COLS:
        x, y = getattr(a, col), getattr(b, col)
        assert np.array_equal(x, y), f"{msg}{col}: {x} != {y}"


def loopback_registry(cfg, S, **kw):
    """Process-backed composer over in-process (but pickle-roundtripped)
    transports: the exact wire surface, no scheduling nondeterminism."""
    return ProcessShardedRegistry(
        cfg, n_shards=S,
        transport_factory=lambda s: LoopbackTransport(ShardHost(cfg, s)),
        **kw)


def drive_ops(reg, n=30, now0=0.0):
    """A mixed op sequence covering every mutating control-plane verb."""
    for pid in range(n):
        reg.register(pid, (pid % 4) * 2, (pid % 4) * 2 + 2,
                     now=now0 + pid * 0.1, profile=f"p{pid % 3}",
                     trust=0.5 + 0.01 * pid, latency_ms=10.0 + pid)
    reg.heartbeat_all(np.arange(n), now0 + 5.0)
    reg.apply_report(ExecReport(
        success=True, chain=[1, 2, 3],
        hops=[HopReport(1, 12.0, True), HopReport(2, 20.0, True)]))
    reg.apply_report(ExecReport(
        success=False, chain=[4, 5],
        hops=[HopReport(4, 30.0, True), HopReport(5, 250.0, False)],
        failed_peer=5))
    for pid in range(0, n, 7):
        reg.heartbeat(pid, now0 + 6.0)
    reg.sweep(now0 + 8.0)
    reg.deregister(3)
    reg.register(3, 0, 2, now=now0 + 9.0)        # re-register keeps seq
    reg.set_trust(7, 0.9)
    reg.register(n, 0, 2, now=now0)              # never heartbeats again
    reg.heartbeat_all(np.arange(n), now0 + 39.0)
    expired = reg.sweep(now0 + 40.0, expire_after_s=20.0)
    assert expired == 1                          # only the silent peer
    reg.sweep(now0 + 41.0, decay_rate=0.01)
    return reg.snapshot(now0 + 41.5)


# ---------------------------------------------------------------------------
# Parity with the in-process twin
# ---------------------------------------------------------------------------


class TestComposerParity:
    @pytest.mark.parametrize("S", [1, 4, 16])
    def test_snapshot_bit_identical(self, gcfg, S):
        twin = ShardedAnchorRegistry(gcfg, n_shards=S)
        with loopback_registry(gcfg, S) as proc:
            assert_tables_equal(drive_ops(twin), drive_ops(proc),
                                msg=f"S={S} ")

    def test_layer_affinity_cross_shard_move(self, gcfg):
        """shard_by='layer': re-registering under a different slot moves
        the peer between shards; the released seq stamp must ride along
        so global registration order (and the composed row order) is
        preserved bit-for-bit."""
        twin = ShardedAnchorRegistry(gcfg, n_shards=4, shard_by="layer")
        with loopback_registry(gcfg, 4, shard_by="layer") as proc:
            for reg in (twin, proc):
                for pid in range(12):
                    reg.register(pid, (pid % 3) * 4, (pid % 3) * 4 + 4,
                                 now=0.1 * pid)
                # move half the peers to new layer slots (likely new shards)
                for pid in range(0, 12, 2):
                    reg.register(pid, ((pid + 1) % 3) * 4,
                                 ((pid + 1) % 3) * 4 + 4, now=2.0)
                reg.heartbeat_all(np.arange(12), 3.0)
            assert_tables_equal(twin.snapshot(4.0), proc.snapshot(4.0))
            for pid in range(12):
                assert proc.owner_of(pid) == twin.owner_of(pid)

    def test_peers_view_matches_twin(self, gcfg):
        twin = ShardedAnchorRegistry(gcfg, n_shards=4)
        with loopback_registry(gcfg, 4) as proc:
            drive_ops(twin)
            drive_ops(proc)
            a, b = twin.peers, proc.peers
            assert list(a.keys()) == list(b.keys())    # global seq order
            for pid in a:
                ra, rb = a[pid], b[pid]
                assert (ra.trust, ra.latency_est_ms, ra.successes,
                        ra.failures, ra.profile) == \
                       (rb.trust, rb.latency_est_ms, rb.successes,
                        rb.failures, rb.profile)
            assert len(twin) == len(proc)

    def test_empty_pull_is_version_stable(self, gcfg):
        with loopback_registry(gcfg, 2) as proc:
            proc.register(0, 0, 2, now=0.0)
            proc.sync(1.0)
            vec = proc.version_vector
            proc.sync(2.0)              # nothing changed: versions hold
            assert proc.version_vector == vec

    def test_hash_vec_matches_scalar(self):
        ids = np.arange(-3, 500, dtype=np.int64)
        want = np.array([stable_peer_hash(int(i)) for i in ids])
        got = stable_peer_hash_vec(ids)
        assert np.array_equal(got, want)

    def test_make_registry_backend_dispatch(self, gcfg):
        cfg = dataclasses.replace(gcfg, control_plane="procs")
        reg = make_registry(cfg, shards=2, backend=None,
                            shard_by="peer")
        try:
            assert isinstance(reg, ProcessShardedRegistry)
        finally:
            reg.close()
        assert isinstance(make_registry(gcfg, shards=2),
                          ShardedAnchorRegistry)
        with pytest.raises(ValueError):
            make_registry(gcfg, shards=2, backend="bogus")


# ---------------------------------------------------------------------------
# RPC determinism: injected clock, exact schedules
# ---------------------------------------------------------------------------


class BlackholeTransport(LoopbackTransport):
    """Mutable loopback: ``mute`` eats posts (dead-air worker),
    ``drop_next`` eats the next n replies AFTER servicing them (the
    lost-reply retry scenario — effects applied, answer lost)."""

    def __init__(self, host):
        super().__init__(host)
        self.mute = False
        self.drop_next = 0

    def post(self, msg):
        if self.mute:
            return
        super().post(msg)
        if self.drop_next > 0 and self._out:
            self._out.pop()
            self.drop_next -= 1


class TestRpcDeterminism:
    POL = RpcPolicy(timeout_s=1.0, retries=2, backoff_base_s=0.05,
                    backoff_factor=2.0)

    def test_timeout_schedule_exact(self, gcfg):
        clock = FakeClock()
        tr = BlackholeTransport(ShardHost(gcfg, 0))
        tr.mute = True
        ch = RpcChannel(tr, self.POL, clock)
        with pytest.raises(RpcTimeout):
            ch.request("ping")
        # retries+1 deadline expiries, exponential backoff between them
        assert ch.stats.rpc_timeouts == 3
        assert ch.stats.rpc_retries == 2
        assert clock.sleeps == [0.05, 0.1]
        assert clock.t == pytest.approx(0.15)    # backoff is the only sleep

    def test_lost_reply_retry_applies_once(self, gcfg):
        """A reply lost in flight: the retry re-posts the same id and the
        worker answers from its dedup cache — exactly-once application."""
        clock = FakeClock()
        host = ShardHost(gcfg, 0)
        tr = BlackholeTransport(host)
        ch = RpcChannel(tr, self.POL, clock)
        tr.drop_next = 1
        fresh, rec = ch.request("register", 7, 0, 2, 0.0, "", None, None,
                                0, None)
        assert fresh and rec.peer_id == 7
        assert ch.stats.rpc_retries == 1
        assert host.dedup_hits == 1
        assert len(host.reg.peers) == 1          # applied once, not twice

    def test_duplicated_reply_is_counted_stale(self, gcfg):
        host = ShardHost(gcfg, 0)
        tr = LoopbackTransport(host)
        real_post = tr.post

        def dup_post(msg):
            real_post(msg)
            if tr._out:
                tr._out.append(tr._out[-1])      # duplicate every reply
        tr.post = dup_post
        ch = RpcChannel(tr, self.POL, FakeClock())
        for pid in range(5):
            ch.request("register", pid, 0, 2, 0.0, "", None, None, pid,
                       None)
        assert len(host.reg.peers) == 5
        assert ch.stats.stale_replies == 4       # dup drains on next collect

    def test_remote_error_not_retried(self, gcfg):
        clock = FakeClock()
        ch = RpcChannel(LoopbackTransport(ShardHost(gcfg, 0)), self.POL,
                        clock)
        with pytest.raises(RpcRemoteError, match="AttributeError"):
            ch.request("no_such_op")
        assert ch.stats.remote_errors == 1
        assert ch.stats.rpc_retries == 0 and clock.sleeps == []

    def test_worker_down_beats_retry_loop(self, gcfg):
        clock = FakeClock()
        tr = BlackholeTransport(ShardHost(gcfg, 0))
        ch = RpcChannel(tr, self.POL, clock)
        tr.mute = True
        tr._alive = False
        with pytest.raises(WorkerDown):
            ch.request("ping")
        assert clock.sleeps == []                # no pointless backoff

    def test_pipelined_interleaved_replies(self, gcfg):
        """Replies collected out of posting order are buffered per id —
        the heartbeat fan-in contract."""
        host = ShardHost(gcfg, 0)
        ch = RpcChannel(LoopbackTransport(host), self.POL, FakeClock())
        rids = [ch.post("register", pid, 0, 2, 0.0, "", None, None, pid,
                        None) for pid in range(6)]
        for rid in reversed(rids):               # collect backwards
            ch.collect(rid)
        assert len(host.reg.peers) == 6
        assert ch.stats.rpc_timeouts == 0


class TestDegradation:
    def make(self, gcfg, S=2):
        clock = FakeClock()
        transports = {}

        def factory(s):
            t = transports[s] = BlackholeTransport(ShardHost(gcfg, s))
            return t
        reg = ProcessShardedRegistry(
            gcfg, n_shards=S, clock=clock,
            policy=RpcPolicy(timeout_s=1.0, retries=2,
                             backoff_base_s=0.05, backoff_factor=2.0),
            transport_factory=factory)
        return reg, transports, clock

    def test_degraded_shard_serves_stale_and_drops_writes(self, gcfg):
        reg, transports, clock = self.make(gcfg)
        for pid in range(10):
            reg.register(pid, 0, 2, now=0.0, trust=0.8)
        t0 = reg.snapshot(1.0)
        assert len(t0.peer_ids) == 10

        transports[1].mute = True
        reg.sync(2.0)
        assert reg.degraded == {1}
        assert clock.sleeps == [0.05, 0.1]       # one full retry ladder
        assert reg.health.rpc_timeouts == 3
        assert reg.health.degraded_windows == 1
        # the composed view still carries shard 1's last slice
        assert len(reg.mirror.materialize(2.0).peer_ids) == 10

        # writes against the sick shard drop (and count) instead of block
        drops0 = reg.health.dropped_writes
        sick = [p for p in range(10) if reg.shard_of(p) == 1]
        reg.set_trust(sick[0], 0.1)
        reg.heartbeat_all(np.arange(10), 3.0)
        reg.sync(3.5)                            # flush -> sick buf dropped
        assert reg.health.dropped_writes > drops0
        # subsequent syncs probe ONCE: no extra backoff sleeps pile up
        assert clock.sleeps == [0.05, 0.1]
        assert reg.health.degraded_windows == 2

        transports[1].mute = False               # recovery
        reg.sync(4.0)
        assert reg.degraded == set()
        assert len(reg.snapshot(5.0).peer_ids) == 10
        reg.close()

    def test_degraded_register_returns_local_record(self, gcfg):
        reg, transports, clock = self.make(gcfg)
        reg.register(0, 0, 2, now=0.0)
        sick = reg.shard_of(99)
        transports[sick].mute = True
        reg.sync(1.0)
        seq_before = reg._seq_next
        rec = reg.register(99, 0, 2, now=1.5, trust=0.7)
        assert rec.peer_id == 99 and rec.trust == 0.7
        assert reg._seq_next == seq_before       # dropped write: no stamp
        assert reg.owner_of(99) is None
        reg.close()

    def test_staleness_grows_and_routing_view_discounts(self, gcfg):
        """A degraded shard's staleness clock stops; with the stale-round
        margin on, its rows (and only its rows) get trust-docked — the
        degradation pricing IS the gossip staleness machinery."""
        cfg = dataclasses.replace(gcfg, gossip_stale_margin=0.05)
        reg, transports, clock = self.make(cfg)
        for pid in range(8):
            reg.register(pid, 0, 2, now=0.0, trust=0.9)
        reg.snapshot(1.0)
        transports[0].mute = True
        reg.sync(2.0)                # shard 0 degrades
        reg.sync(30.0)               # probe fails; shard 1 refreshes
        stale = reg.staleness(30.0)
        assert stale[0] > 20.0 and stale[1] == 0.0
        full = reg.mirror.materialize(30.0)
        view = reg.routing_view(30.0)
        sick_rows = np.isin(
            full.peer_ids,
            [p for p in range(8) if reg.shard_of(p) == 0])
        assert sick_rows.any() and (~sick_rows).any()
        assert np.all(view.trust[sick_rows] < full.trust[sick_rows])
        assert np.all(view.trust[~sick_rows] == full.trust[~sick_rows])
        reg.close()


# ---------------------------------------------------------------------------
# Real processes: kill -9 chaos, restore, re-adopt
# ---------------------------------------------------------------------------


class TestProcessChaos:
    def test_real_worker_parity_kill_restart(self, gcfg):
        with ProcessShardedRegistry(gcfg, n_shards=4) as reg:
            twin = ShardedAnchorRegistry(gcfg, n_shards=4)
            t_proc = drive_ops(reg, n=40)
            t_twin = drive_ops(twin, n=40)
            assert_tables_equal(t_twin, t_proc, msg="pre-kill ")

            victim = 1
            reg.kill_worker(victim)
            assert reg.dead_workers() == [victim]
            # degraded serving: the cadence keeps going on the stale slice
            t_deg = reg.snapshot(50.0)
            assert np.array_equal(t_deg.peer_ids, t_proc.peer_ids)
            assert reg.health.degraded_windows >= 1

            reg.restart_worker(victim)           # restore from own mirror
            assert reg.health.worker_restarts == 1
            assert reg.dead_workers() == []
            t_back = reg.snapshot(51.0)
            assert_tables_equal(t_proc, t_back, msg="post-restore ")
            # ground truth: the respawned worker really holds the rows
            exports = [reg.channels[s].request("export") for s in range(4)]
            assert sum(len(e.peer_ids) for e in exports) == \
                len(t_proc.peer_ids)

    def test_writes_after_restore_land_on_fresh_worker(self, gcfg):
        with ProcessShardedRegistry(gcfg, n_shards=2) as reg:
            for pid in range(12):
                reg.register(pid, 0, 2, now=0.0, trust=0.5)
            reg.snapshot(1.0)
            reg.kill_worker(0)
            reg.restart_worker(0)
            on0 = [p for p in range(12) if reg.shard_of(p) == 0]
            reg.set_trust(on0[0], 0.99)
            t = reg.snapshot(2.0)
            row = t.peer_ids == on0[0]
            assert t.trust[row][0] == pytest.approx(0.99)

    def test_replicated_anchor_ledger_restore(self, gcfg):
        cfg = dataclasses.replace(gcfg, control_plane="procs")
        rep = ReplicatedAnchor(cfg, n_backups=1, shards=4)
        prim = rep.primary
        assert isinstance(prim, ProcessShardedRegistry)
        assert isinstance(rep.replicas[1], ShardedAnchorRegistry)
        try:
            for pid in range(32):
                rep.register(pid, 0, 2, now=pid * 0.1, trust=0.7)
            rep.heartbeat_all(np.arange(32), 3.0)
            prim.sync(3.5)
            rep.tick(prim.cfg.gossip_period_s + 10.0)   # replicate
            t0 = rep.snapshot(4.0)

            k = 2
            prim.kill_worker(k)
            # ledger restore needs a live worker first
            with pytest.raises(WorkerDown):
                rep.restore_shard(k)
            assert len(rep.snapshot(5.0).peer_ids) == 32   # still serving
            prim.restart_worker(k)
            assert rep.restore_shard(k)
            t2 = rep.snapshot(6.0)
            assert_tables_equal(t0, t2, msg="ledger-restore ")
            assert prim.health.worker_restarts == 1
        finally:
            prim.close()

    def test_shards_one_backup_speaks_shard_surface(self, gcfg):
        """A procs primary replicates per shard even at S=1; the backup
        must be upgraded to the sharded in-process registry."""
        cfg = dataclasses.replace(gcfg, control_plane="procs")
        rep = ReplicatedAnchor(cfg, n_backups=1, shards=1)
        try:
            assert hasattr(rep.replicas[1], "adopt_shard_state")
            rep.register(0, 0, 2, now=0.0)
            rep.primary.sync(0.5)
            rep.tick(cfg.gossip_period_s + 1.0)
            assert len(rep.replicas[1].snapshot(1.0).peer_ids) == 1
        finally:
            rep.primary.close()


# ---------------------------------------------------------------------------
# Testbed fault injection (the fixed error path + the new chaos mode)
# ---------------------------------------------------------------------------


class TestCrashAnchorShard:
    def test_unsharded_anchor_rejected_before_any_crash(self, gcfg):
        from repro.sim.testbed import build_scaling_testbed
        bed = build_scaling_testbed(16, cfg=gcfg, seed=0, shards=1)
        with pytest.raises(ValueError, match="sharded anchor"):
            bed.crash_anchor_shard(0)
        assert all(p.alive for p in bed.peers.values())   # nothing mutated

    def test_kill_worker_rejected_on_inproc_before_any_crash(self, gcfg):
        from repro.sim.testbed import build_scaling_testbed
        bed = build_scaling_testbed(16, cfg=gcfg, seed=0, shards=4)
        with pytest.raises(ValueError, match="process-backed"):
            bed.crash_anchor_shard(1, kill_worker=True)
        assert all(p.alive for p in bed.peers.values())   # guard-first

    def test_kill_worker_on_process_backend(self, gcfg):
        from repro.sim.testbed import build_scaling_testbed
        cfg = dataclasses.replace(gcfg, control_plane="procs")
        bed = build_scaling_testbed(24, cfg=cfg, seed=0, shards=4)
        try:
            bed.anchor.snapshot(0.5)
            pids = bed.crash_anchor_shard(1, kill_worker=True)
            assert pids and all(not bed.peers[p].alive for p in pids)
            assert 1 in bed.anchor._dead
            # the control plane keeps composing around the dead shard
            t = bed.anchor.snapshot(1.0)
            assert len(t.peer_ids) == 24
        finally:
            bed.anchor.close()


# ---------------------------------------------------------------------------
# Seeded reply scrambling (always-run cousin of the hypothesis property)
# ---------------------------------------------------------------------------


class ScrambleTransport(LoopbackTransport):
    """Loopback whose reply queue is shuffled (and sometimes duplicated)
    before every poll — out-of-order, duplicated, interleaved delivery."""

    def __init__(self, host, rng, dup_p=0.2):
        super().__init__(host)
        self.rng = rng
        self.dup_p = dup_p

    def poll(self, timeout_s):
        if self._out:
            buf = list(self._out)
            self.rng.shuffle(buf)
            if self.rng.random() < self.dup_p:
                buf.append(buf[self.rng.integers(len(buf))])
            self._out.clear()
            self._out.extend(buf)
        return super().poll(timeout_s)


class TestScrambledReplies:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_parity_under_scrambled_delivery(self, gcfg, seed):
        rng = np.random.default_rng(seed)
        twin = ShardedAnchorRegistry(gcfg, n_shards=4)
        reg = ProcessShardedRegistry(
            gcfg, n_shards=4, clock=FakeClock(),
            transport_factory=lambda s: ScrambleTransport(
                ShardHost(gcfg, s), rng))
        with reg:
            for rnd in range(3):                 # interleave across rounds
                now0 = rnd * 100.0
                a = drive_ops(twin, n=20, now0=now0)
                b = drive_ops(reg, n=20, now0=now0)
                assert_tables_equal(a, b, msg=f"seed={seed} round={rnd} ")
