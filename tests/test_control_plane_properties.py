"""Property tests: composer parity under adversarial reply delivery.

Two layers, both hypothesis-driven (skipped gracefully when hypothesis
is absent — see ``_hyp``):

* **Channel level** — a transport that reorders and duplicates replies
  per a drawn schedule, under a full ``ProcessShardedRegistry`` op
  sequence spanning several sync rounds: composed snapshots must stay
  bit-identical to the in-process twin, because replies are matched by
  request id and the worker dedups re-posts.
* **Delta level** — shard pulls collected in order but *applied* to a
  mirror in a drawn permutation with drawn duplicates: gaps raise
  ``DeltaGapError`` and are repaired by the full-sync fallback, after
  which the mirror must compose exactly the hosts' ground-truth state.
"""
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).parent))
from _hyp import given, settings, st  # noqa: E402

from repro.configs.base import GTRACConfig  # noqa: E402
from repro.control_plane import (  # noqa: E402
    FakeClock,
    LoopbackTransport,
    ProcessShardedRegistry,
    ShardHost,
)
from repro.core.sharding import ShardedAnchorRegistry  # noqa: E402
from repro.core.types import ExecReport, HopReport  # noqa: E402
from repro.sync.delta import DeltaGapError  # noqa: E402
from repro.sync.seeker import SeekerCache  # noqa: E402

SNAP_COLS = ("peer_ids", "layer_start", "layer_end", "trust",
             "latency_ms", "alive")


def assert_tables_equal(a, b):
    for col in SNAP_COLS:
        assert np.array_equal(getattr(a, col), getattr(b, col)), col


class ScheduledScrambleTransport(LoopbackTransport):
    """Loopback whose reply queue is permuted/duplicated by a drawn
    integer schedule (consumed round-robin), so hypothesis shrinks over
    delivery orders instead of RNG seeds."""

    def __init__(self, host, schedule):
        super().__init__(host)
        self.schedule = list(schedule) or [0]
        self._i = 0

    def _next(self, n):
        v = self.schedule[self._i % len(self.schedule)]
        self._i += 1
        return v % n

    def poll(self, timeout_s):
        if len(self._out) > 1:
            buf = list(self._out)
            # drawn rotation = out-of-order delivery
            k = self._next(len(buf))
            buf = buf[k:] + buf[:k]
            # drawn duplication: re-append one reply
            if self._next(4) == 0:
                buf.append(buf[self._next(len(buf))])
            self._out.clear()
            self._out.extend(buf)
        return super().poll(timeout_s)


def drive(reg, rounds, n=18):
    """Multi-round mixed op sequence; returns the final snapshot."""
    t = None
    for r in range(rounds):
        now0 = 50.0 * r
        for pid in range(n):
            reg.register(pid, (pid % 3) * 2, (pid % 3) * 2 + 2,
                         now=now0 + pid * 0.1, trust=0.5 + 0.02 * (pid % 9))
        reg.heartbeat_all(np.arange(n), now0 + 2.0)
        reg.apply_report(ExecReport(
            success=True, chain=[0, 1],
            hops=[HopReport(0, 10.0, True), HopReport(1, 11.0, True)]))
        reg.apply_report(ExecReport(
            success=False, chain=[2],
            hops=[HopReport(2, 300.0, False)], failed_peer=2))
        reg.deregister((r + 3) % n)
        reg.sweep(now0 + 3.0)
        t = reg.snapshot(now0 + 4.0)
    return t


class TestScrambledChannel:
    @settings(max_examples=25, deadline=None)
    @given(schedule=st.lists(st.integers(0, 63), min_size=1, max_size=48),
           shards=st.integers(1, 5))
    def test_parity_under_drawn_delivery_order(self, schedule, shards):
        cfg = GTRACConfig()
        twin = ShardedAnchorRegistry(cfg, n_shards=shards)
        reg = ProcessShardedRegistry(
            cfg, n_shards=shards, clock=FakeClock(),
            transport_factory=lambda s: ScheduledScrambleTransport(
                ShardHost(cfg, s), schedule))
        with reg:
            a = drive(twin, rounds=3)
            b = drive(reg, rounds=3)
            assert_tables_equal(a, b)
            assert reg.degraded == set()


class TestScrambledDeltaApplication:
    @settings(max_examples=25, deadline=None)
    @given(order=st.lists(st.integers(0, 10_000), min_size=6, max_size=24),
           dups=st.lists(st.booleans(), min_size=6, max_size=24),
           seed=st.integers(0, 2**32 - 1))
    def test_mirror_converges_after_repair(self, order, dups, seed):
        """Pulls applied out of order / duplicated across rounds: gapped
        deltas fail loudly, duplicates are discarded, and one full-pull
        repair pass per shard re-converges the mirror to ground truth."""
        S = 3
        cfg = GTRACConfig()
        rng = np.random.default_rng(seed)
        hosts = [ShardHost(cfg, s) for s in range(S)]

        def shard_of(pid):
            return pid % S

        pulls = []                    # (shard, delta, hb) in true order
        have = [-1] * S
        for rnd in range(4):
            now0 = 10.0 * rnd
            for pid in rng.integers(0, 30, size=6):
                hosts[shard_of(pid)].reg.register(
                    int(pid), 0, 2, now=now0,
                    trust=float(rng.uniform(0.3, 1.0)))
            for s in range(S):
                hosts[s].reg.heartbeat_all(
                    [p for p in range(30) if shard_of(p) == s], now0 + 1.0)
            drop = int(rng.integers(0, 30))
            hosts[shard_of(drop)].reg.deregister(drop)
            for s in range(S):
                delta, hb = hosts[s]._op_pull(have[s])
                have[s] = delta.new_version
                pulls.append((s, delta, hb))

        mirror = SeekerCache(cfg, S, now=0.0)
        now = 100.0
        # drawn application order with drawn duplicates
        seq = list(range(len(pulls)))
        perm = sorted(seq, key=lambda i: (order[i % len(order)], i))
        for i, j in enumerate(perm):
            reps = 2 if dups[j % len(dups)] else 1
            for _ in range(reps):
                s, delta, hb = pulls[j]
                try:
                    mirror.apply(delta, now)
                except DeltaGapError:
                    continue          # repaired below
                mirror.refresh_heartbeats(s, np.asarray(hb, np.float64),
                                          now)
        # repair pass: one full pull per shard (the anti-entropy path)
        for s in range(S):
            delta, hb = hosts[s]._op_pull(-1)
            if delta.new_version < mirror.version_vector[s]:
                mirror.invalidate_shard(s)     # regression guard
            mirror.apply(delta, now)
            mirror.refresh_heartbeats(s, np.asarray(hb, np.float64), now)

        # ground truth: compose the hosts' exports through a fresh mirror
        truth = SeekerCache(cfg, S, now=0.0)
        for s in range(S):
            delta, hb = hosts[s]._op_pull(-1)
            truth.apply(delta, now)
            truth.refresh_heartbeats(s, np.asarray(hb, np.float64), now)
        assert_tables_equal(truth.materialize(now), mirror.materialize(now))
        assert mirror.version_vector == truth.version_vector
