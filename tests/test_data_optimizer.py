"""Data pipeline determinism/packing + AdamW correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import TrainConfig
from repro.data.pipeline import DataConfig, EOS, SyntheticLMStream
from repro.trainer import optimizer as opt
from repro.trainer.schedule import warmup_cosine


class TestData:
    def test_deterministic_in_seed_host_step(self):
        a = SyntheticLMStream(DataConfig(256, 64, 4, seed=1)).batch(3)
        b = SyntheticLMStream(DataConfig(256, 64, 4, seed=1)).batch(3)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = SyntheticLMStream(DataConfig(256, 64, 4, seed=2)).batch(3)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_shapes_and_labels_are_shifted(self):
        d = SyntheticLMStream(DataConfig(256, 64, 4)).batch(0)
        assert d["tokens"].shape == (4, 64) == d["labels"].shape
        # labels are next-token shifted: rows agree on the overlap
        np.testing.assert_array_equal(d["tokens"][:, 1:], d["labels"][:, :-1])

    def test_mask_covers_non_eos(self):
        d = SyntheticLMStream(DataConfig(256, 64, 4)).batch(0)
        np.testing.assert_array_equal(d["mask"], d["labels"] != EOS)

    def test_host_sharding_partitions_batch(self):
        full = SyntheticLMStream(DataConfig(256, 32, 8, num_hosts=1))
        h0 = SyntheticLMStream(DataConfig(256, 32, 8, num_hosts=2,
                                          host_id=0))
        assert h0.local_batch == 4 and full.local_batch == 8

    def test_tokens_in_vocab(self):
        d = SyntheticLMStream(DataConfig(100, 128, 2)).batch(5)
        assert d["tokens"].min() >= 0 and d["tokens"].max() < 100


class TestAdamW:
    def test_first_step_is_signed_lr(self):
        """After bias correction, |update| == lr for a fresh moment state
        (no weight decay on 1-D params)."""
        tcfg = TrainConfig(weight_decay=0.0)
        params = {"w": jnp.array([1.0, -2.0, 3.0])}
        grads = {"w": jnp.array([0.5, -0.1, 0.2])}
        state = opt.init(params)
        lr = jnp.float32(0.01)
        new, state, _ = opt.update(params, grads, state, tcfg, lr)
        delta = np.asarray(params["w"] - new["w"])
        np.testing.assert_allclose(np.abs(delta), 0.01 * np.ones(3),
                                   rtol=1e-4)
        np.testing.assert_allclose(np.sign(delta),
                                   np.sign(np.asarray(grads["w"])))

    def test_weight_decay_on_matrices_only(self):
        tcfg = TrainConfig(weight_decay=0.1)
        params = {"m": jnp.ones((2, 2)), "v": jnp.ones((2,))}
        grads = jax.tree.map(jnp.zeros_like, params)
        state = opt.init(params)
        new, _, _ = opt.update(params, grads, state, tcfg, jnp.float32(0.1))
        assert float(new["m"][0, 0]) < 1.0      # decayed
        assert float(new["v"][0]) == 1.0        # not decayed

    def test_grad_clip(self):
        g = {"w": jnp.full((4,), 100.0)}
        clipped, norm = opt.clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(200.0)
        assert float(jnp.linalg.norm(clipped["w"])) == pytest.approx(1.0)

    @given(st.integers(1, 999))
    @settings(max_examples=50, deadline=None)
    def test_schedule_bounds(self, step):
        tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=100,
                           total_steps=1000)
        lr = float(warmup_cosine(tcfg)(jnp.int32(step)))
        assert 0.0 <= lr <= 1e-3 + 1e-9

    def test_moments_are_f32_and_param_shaped(self):
        params = {"w": jnp.ones((3, 3), jnp.bfloat16)}
        state = opt.init(params)
        assert state["mu"]["w"].dtype == jnp.float32
        assert state["mu"]["w"].shape == (3, 3)
