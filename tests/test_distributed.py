"""Distributed tests. Multi-device cases run in SUBPROCESSES that set
--xla_force_host_platform_device_count themselves (the main test process
must keep the default single CPU device — see conftest)."""
import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8, timeout=560):
    """Run a python snippet in a subprocess with N host devices."""
    prelude = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={n_devices}'\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c",
                           prelude + textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    return proc.stdout


def test_sharded_train_step_matches_single_device():
    """A (2,4) data×model mesh with FSDP×TP rules + activation constraints
    computes the same loss as unsharded execution."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.distributed import sharding as sh
        from repro.models.api import build_model
        from repro.trainer import optimizer as opt
        from repro.trainer.train_loop import make_train_step

        cfg = get_config('tinyllama-1.1b').reduced(
            num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
            head_dim=16, d_ff=128, vocab_size=64,
            activation_dtype='float32', param_dtype='float32')
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        tok = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 64)
        batch = {'tokens': tok, 'labels': tok}
        tcfg = TrainConfig(warmup_steps=1, total_steps=2)
        step = make_train_step(model, tcfg)
        o0 = opt.init(params)

        # single device reference
        p_ref, _, m_ref = jax.jit(step)(params, o0, batch)

        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        with mesh, sh.activation_policy(mesh):
            ps = sh.param_shardings(mesh, params)
            bs = jax.tree.map(lambda s: NamedSharding(mesh, s),
                              sh.batch_pspecs(mesh, batch))
            params_d = jax.device_put(params, ps)
            batch_d = jax.device_put(batch, bs)
            o0_d = opt.init(params_d)
            p_sh, _, m_sh = jax.jit(step)(params_d, o0_d, batch_d)
        np.testing.assert_allclose(float(m_ref['loss']),
                                   float(m_sh['loss']), rtol=1e-4)
        for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                        jax.tree_util.tree_leaves(p_sh)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-4)
        print('SHARDED == SINGLE OK')
    """)


def test_elastic_remesh_reshard():
    """Lose 4 of 8 devices -> rebuild (1,4) mesh, reshard params, step."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.elastic import surviving_mesh, reshard_params
        from repro.configs import get_config
        from repro.models.api import build_model

        cfg = get_config('smollm-360m').reduced(num_layers=2, d_model=64,
                                                num_heads=4, num_kv_heads=2,
                                                head_dim=16, d_ff=128,
                                                vocab_size=64)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        mesh0 = jax.make_mesh((2, 4), ('data', 'model'))
        from repro.distributed.sharding import param_shardings
        params = jax.device_put(params, param_shardings(mesh0, params))
        lost = [d.id for d in jax.devices()[:4]]
        mesh1 = surviving_mesh(('data', 'model'), (2, 4), lost)
        assert mesh1.devices.shape == (1, 4), mesh1.devices.shape
        params1 = reshard_params(params, mesh1)
        tok = jnp.zeros((4, 8), jnp.int32)
        loss = model.loss_fn(params1, {'tokens': tok, 'labels': tok})
        assert jnp.isfinite(loss)
        print('ELASTIC OK', mesh1.devices.shape)
    """)


def test_pipeline_shard_map_matches_sequential():
    """4-stage ppermute pipeline == sequential stage application."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_shard_map

        S = 4
        mesh = jax.make_mesh((S,), ('stage',))
        Ws = jax.random.normal(jax.random.PRNGKey(0), (S, 16, 16)) * 0.3

        def stage_fn(stage, x):
            W = jax.lax.dynamic_index_in_dim(Ws, stage, 0, keepdims=False)
            return jnp.tanh(x @ W)

        M, b = 8, 4
        x = jax.random.normal(jax.random.PRNGKey(1), (M, b, 16))
        piped = pipeline_shard_map(stage_fn, mesh, n_microbatches=M)
        y = piped(x)
        # sequential reference
        ref = x
        for s in range(S):
            ref = jnp.tanh(ref @ Ws[s])
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   atol=1e-5)
        print('PIPELINE OK')
    """, n_devices=4)


def test_compressed_psum_error_feedback():
    """int8 grad all-reduce: one step is approximate; error feedback makes
    the bias vanish over repeated steps."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collectives import compressed_psum
        from repro.distributed.compat import shard_map_nocheck

        mesh = jax.make_mesh((8,), ('data',))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 128))

        def one_round(g, r):
            return compressed_psum(g, 'data', r)

        f = shard_map_nocheck(one_round, mesh=mesh,
                              in_specs=(P('data'), P('data')),
                              out_specs=(P('data'), P('data')))
        want = jnp.mean(g, axis=0)
        r = jnp.zeros_like(g)
        acc_true = jnp.zeros(128)
        acc_comp = jnp.zeros(128)
        for _ in range(30):
            out, r = f(g, r)
            acc_comp = acc_comp + out[0]
            acc_true = acc_true + want
        rel = float(jnp.linalg.norm(acc_comp - acc_true) /
                    jnp.linalg.norm(acc_true))
        assert rel < 0.02, rel     # EF drives accumulated bias to ~0
        single, _ = f(g, jnp.zeros_like(g))
        rel1 = float(jnp.linalg.norm(single[0] - want) /
                     jnp.linalg.norm(want))
        assert rel1 < 0.2           # single round is lossy but close
        print('COMPRESSED PSUM OK', rel, rel1)
    """)


def test_dryrun_single_cell_small_mesh():
    """The dry-run machinery end-to-end on an 8-device mesh with a reduced
    config (fast proxy for the 512-device production run)."""
    run_with_devices("""
        import jax, dataclasses
        from jax.sharding import NamedSharding
        from repro.configs import get_config, get_shape
        from repro.configs.base import ShapeConfig
        from repro.distributed import sharding as sh
        from repro.launch import roofline as rl
        from repro.launch.dryrun import build_lowerable

        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        shape = ShapeConfig('train_tiny', 128, 8, 'train')
        with mesh, sh.activation_policy(mesh):
            fn, args = build_lowerable(
                'tinyllama-1.1b', shape, mesh,
                overrides={'num_layers': 2, 'd_model': 64, 'num_heads': 4,
                           'num_kv_heads': 2, 'head_dim': 16, 'd_ff': 128,
                           'vocab_size': 256})
            compiled = fn.lower(*args).compile()
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        roof = rl.derive('tinyllama-1.1b', shape, 'test', 8, cost,
                         compiled.as_text(), get_config('tinyllama-1.1b'))
        assert roof.flops_per_device > 0
        assert roof.collective_ops > 0    # FSDP gathers + grad reductions
        print('DRYRUN-SMALL OK', roof.dominant, roof.collective_ops)
    """)


def test_decode_cell_small_mesh():
    run_with_devices("""
        import jax
        from repro.configs.base import ShapeConfig
        from repro.distributed import sharding as sh
        from repro.launch.dryrun import build_lowerable

        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        shape = ShapeConfig('decode_tiny', 256, 8, 'decode')
        with mesh, sh.activation_policy(mesh):
            fn, args = build_lowerable(
                'granite-34b', shape, mesh,
                overrides={'num_layers': 2, 'd_model': 64, 'num_heads': 4,
                           'num_kv_heads': 1, 'head_dim': 16, 'd_ff': 128,
                           'vocab_size': 256, 'max_position': 512})
            compiled = fn.lower(*args).compile()
        print('DECODE-MQA OK')
    """)
