"""Anchor failover + hedged execution (beyond-paper scale features)."""
import numpy as np
import pytest

from repro.configs.base import GTRACConfig
from repro.core.failover import ReplicatedAnchor
from repro.core.hedging import HedgedChainExecutor
from repro.core.registry import SeekerCache
from repro.core.routing import gtrac_route
from repro.core.types import ExecReport, HopReport
from repro.serving.api import SubmitSpec


@pytest.fixture
def gcfg():
    return GTRACConfig()


class TestReplicatedAnchor:
    def _populate(self, anchor, n=6):
        for pid in range(n):
            seg = (pid % 2) * 3
            anchor.register(pid, seg, seg + 3, now=0.0)
            anchor.heartbeat(pid, 0.0)

    def test_backup_catches_up_on_tick(self, gcfg):
        ra = ReplicatedAnchor(gcfg, n_backups=2)
        self._populate(ra)
        ra.apply_report(ExecReport(False, [0], [HopReport(0, 1.0, False)],
                                   failed_peer=0))
        assert len(ra.replicas[1].peers) == 0     # not yet replicated
        ra.tick(gcfg.gossip_period_s + 0.1)
        assert len(ra.replicas[1].peers) == 6
        assert ra.replicas[1].peers[0].trust == ra.primary.peers[0].trust

    def test_failover_promotes_backup_with_state(self, gcfg):
        ra = ReplicatedAnchor(gcfg, n_backups=1)
        self._populate(ra)
        ra.tick(gcfg.gossip_period_s + 0.1)       # replicate
        old_primary = ra.primary
        ra.crash_primary()
        assert ra.maybe_failover(now=100.0)
        assert ra.primary is not old_primary
        assert len(ra.primary.peers) == 6         # state survived
        assert ra.failovers == 1

    def test_staleness_bounded_by_sync_period(self, gcfg):
        """Failover loses at most the updates since the last tick — the
        seeker-visible effect is bounded trust staleness, not data loss."""
        ra = ReplicatedAnchor(gcfg, n_backups=1)
        self._populate(ra)
        ra.tick(gcfg.gossip_period_s + 0.1)
        t_before = ra.primary.peers[0].trust
        ra.apply_report(ExecReport(False, [0], [HopReport(0, 1.0, False)],
                                   failed_peer=0))   # post-sync update
        ra.crash_primary()
        ra.maybe_failover(now=100.0)
        assert ra.primary.peers[0].trust == pytest.approx(t_before)

    def test_routing_continues_through_failover(self, gcfg):
        ra = ReplicatedAnchor(gcfg, n_backups=1)
        self._populate(ra)
        ra.tick(gcfg.gossip_period_s + 0.1)
        cache = SeekerCache(ra.primary, gcfg, now=0.0)
        ra.crash_primary()
        # seeker still routes from its cached view mid-failover
        r = gtrac_route(cache.view(), 6, gcfg, tau=0.0)
        assert r.feasible
        ra.maybe_failover(now=100.0)
        # registry state carried over but heartbeats are stale (TTL) —
        # peers re-heartbeat to the new primary and recover
        for pid in range(6):
            ra.heartbeat(pid, 101.0)
        r2 = gtrac_route(ra.snapshot(101.0), 6, gcfg, tau=0.0)
        assert r2.feasible

    def test_no_live_replica_raises(self, gcfg):
        ra = ReplicatedAnchor(gcfg, n_backups=1)
        ra.crash_primary()
        ra.alive[1] = False
        with pytest.raises(RuntimeError):
            ra.maybe_failover(now=100.0)


class TestHedging:
    def _table(self, gcfg, latencies):
        from repro.core.registry import AnchorRegistry
        a = AnchorRegistry(gcfg)
        for pid, lat in enumerate(latencies):
            a.register(pid, 0, 3, now=0.0, latency_ms=lat)
            a.heartbeat(pid, 0.0)
        a.register(99, 3, 6, now=0.0, latency_ms=50.0)
        a.heartbeat(99, 0.0)
        return a.snapshot(0.0)

    def test_hedge_wins_against_straggler(self, gcfg):
        t = self._table(gcfg, [100.0, 100.0])
        lat = {0: 1000.0, 1: 80.0, 99: 50.0}   # peer 0 straggles hard

        def hop(pid, k, payload):
            return payload, lat[pid], True

        ex = HedgedChainExecutor(gcfg, hop, quantile_factor=2.0)
        report, _ = ex.execute([0, 99], t)
        assert report.success
        assert ex.stats.hedges_fired == 1 and ex.stats.hedges_won == 1
        # winner: trigger (200) + backup (80) = 280 < 1000
        assert report.hops[0].latency_ms == pytest.approx(280.0)
        assert report.chain[0] == 1               # backup took over

    def test_no_hedge_when_fast(self, gcfg):
        t = self._table(gcfg, [100.0, 100.0])

        def hop(pid, k, payload):
            return payload, 90.0, True

        ex = HedgedChainExecutor(gcfg, hop)
        report, _ = ex.execute([0, 99], t)
        assert report.success and ex.stats.hedges_fired == 0

    def test_hedge_rescues_failure_without_repair(self, gcfg):
        t = self._table(gcfg, [100.0, 100.0])
        calls = []

        def hop(pid, k, payload):
            calls.append(pid)
            if pid == 0:
                return payload, 150.0, False   # fail (slow detect)
            return payload, 60.0, True

        ex = HedgedChainExecutor(gcfg, hop)
        report, _ = ex.execute([0, 99], t)
        assert report.success
        assert not report.repaired             # hedge won before repair
        assert ex.stats.hedges_won == 1

    def test_hedged_window_serving(self):
        """cfg.hedge_enabled threads HedgedChainExecutor through
        GTRACPipelineServer.run_queue; hedge-fire counts surface in
        ServeMetrics and decoded tokens match the unhedged server (the
        backup replica runs the identical stage compute)."""
        import jax
        from repro.configs import get_config
        from repro.core.executor import ChainExecutor
        from repro.models.api import build_model
        from repro.serving.gtrac_serve import GTRACPipelineServer
        cfg = get_config("gpt2-large").reduced(num_layers=4, vocab_size=128,
                                               remat=False)
        params = build_model(cfg).init(jax.random.PRNGKey(3))
        prompt = np.arange(1, 9)

        def serve(hedged):
            gcfg = GTRACConfig(hedge_enabled=hedged,
                               # trigger ~0: every hop exceeds it, so the
                               # hedge fires deterministically whenever a
                               # same-segment replacement exists
                               hedge_quantile_factor=0.05)
            srv = GTRACPipelineServer(cfg, params, layers_per_stage=2,
                                      replicas={"golden": 2}, gcfg=gcfg,
                                      seed=0)
            for _ in range(2):
                srv.submit(SubmitSpec(prompt=prompt, max_new_tokens=4))
            return srv.run_queue()

        plain = serve(False)
        hedged = serve(True)
        assert all(isinstance(r.executor, ChainExecutor) for r in plain)
        assert all(isinstance(r.executor, HedgedChainExecutor)
                   for r in hedged)
        for rp, rh in zip(plain, hedged):
            assert rh.metrics.tokens == 4
            assert rh.output == rp.output          # same real compute
            assert rp.metrics.hedges_fired == 0
        assert sum(r.metrics.hedges_fired for r in hedged) > 0
        assert all(r.metrics.hedges_won <= r.metrics.hedges_fired
                   for r in hedged)

    def test_tail_latency_improves_under_stragglers(self, gcfg):
        """P99 with hedging < without, on a lognormal-tailed peer pool."""
        t = self._table(gcfg, [100.0] * 4)

        def make_hop(seed):
            r = np.random.default_rng(seed)

            def hop(pid, k, payload):
                base = 100.0 if pid != 99 else 50.0
                lat = base * float(r.lognormal(0, 1.0))
                return payload, lat, True

            return hop

        from repro.core.executor import ChainExecutor
        plain, hedged = [], []
        for i in range(300):
            e1 = ChainExecutor(gcfg, make_hop(i))
            r1, _ = e1.execute([0, 99], t)
            plain.append(r1.total_latency_ms)
            e2 = HedgedChainExecutor(gcfg, make_hop(i), quantile_factor=2.0)
            r2, _ = e2.execute([0, 99], t)
            hedged.append(r2.total_latency_ms)
        assert np.percentile(hedged, 99) < np.percentile(plain, 99)
        assert np.mean(hedged) <= np.mean(plain) * 1.05  # no mean regression
