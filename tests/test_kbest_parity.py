"""K-best routing backend parity: the numpy planner DP, the jnp
``layered_dp_kbest``, and the Pallas ``tropical_route_kbest`` kernel
(interpret mode) must agree bit-for-bit — same chains, same rank order,
same tie-breaking — including tie-heavy cost landscapes, infeasible rows,
and degenerate empty batches. Plans built from the device path must drive
``ChainExecutor`` failover splicing exactly like numpy-built plans.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.executor import ChainExecutor
from repro.core.planner import RoutePlanner
from repro.core.routing_jax import (
    backtrack_kbest,
    effective_costs,
    layered_dp_kbest,
    route_batched,
    route_batched_kbest,
)
from repro.kernels import ref
from repro.kernels.tropical_route import tropical_route, tropical_route_kbest
from repro.serving.batch_router import plan_batched

from conftest import build_layered_anchor

INF = 1e38


def _numpy_kbest_chains(planner, t, cfg, tau, k):
    """Planner DP chains in raw rank order (reorder=False) as row lists."""
    w = t.latency_ms + (1.0 - t.trust) * cfg.request_timeout_ms
    mask = t.alive & (t.trust >= tau)
    return planner.solve_kbest(t, w, mask, k=k, reorder=False)


def _device_kbest_chains(t, cfg, taus, k, L, planner, use_kernel):
    hops, costs = route_batched_kbest(
        t, L, cfg, taus, k_max=L, k_best=k, planner=planner,
        use_kernel=use_kernel, interpret=use_kernel)
    out = []
    for r in range(len(taus)):
        chains, ccosts = [], []
        for j in range(k):
            if not float(costs[r, j]) < INF:
                break
            chains.append([int(x) for x in hops[r, j] if x >= 0])
            ccosts.append(float(costs[r, j]))
        out.append((chains, ccosts))
    return out


class TestThreeBackendParity:
    @pytest.mark.parametrize("use_kernel", [False, True])
    def test_chains_and_ranks_match_numpy(self, gcfg, use_kernel):
        """Raw DP rank order identical across backends on random tables
        (integer latencies: exactly representable in f32 and f64, so the
        backends see identical tie structure)."""
        for seed in range(3):
            anchor = build_layered_anchor(gcfg, L=12, replicas=4, seed=seed)
            t = anchor.snapshot(0.0)
            t.latency_ms[:] = np.round(t.latency_ms)
            t.trust[:] = np.round(t.trust * 4) / 4    # induce cost ties
            planner = RoutePlanner(12, k_best=4)
            taus = np.array([0.0, 0.6, 0.8])
            dev = _device_kbest_chains(t, gcfg, taus, 4, 12, planner,
                                       use_kernel)
            for i, tau in enumerate(taus):
                chains, costs = _numpy_kbest_chains(planner, t, gcfg,
                                                    float(tau), 4)
                dchains, dcosts = dev[i]
                assert dchains == chains
                for c, d in zip(costs, dcosts):
                    assert d == pytest.approx(c, rel=1e-5)

    def test_jnp_and_kernel_bitwise_identical(self, gcfg):
        """layered_dp_kbest and the Pallas kernel share f32 arithmetic:
        distK/pedge/prank must be bitwise equal, padded blocks included."""
        anchor = build_layered_anchor(gcfg, L=12, replicas=5, seed=1)
        t = anchor.snapshot(0.0)
        taus = np.linspace(0, 0.9, 5)       # R=5: forces blk_r padding
        costs = effective_costs(jnp.asarray(t.latency_ms, jnp.float32),
                                jnp.asarray(t.trust, jnp.float32),
                                jnp.asarray(t.alive),
                                jnp.asarray(taus, jnp.float32),
                                gcfg.request_timeout_ms)
        starts = jnp.asarray(t.layer_start, jnp.int32)
        ends = jnp.asarray(t.layer_end, jnp.int32)
        d1, e1, r1 = layered_dp_kbest(starts, ends, costs, total_layers=12,
                                      k_best=3)
        d2, e2, r2 = tropical_route_kbest(starts, ends, costs,
                                          total_layers=12, k_best=3,
                                          blk_r=4, interpret=True)
        np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
        np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))
        np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))

    def test_kernel_matches_numpy_oracle(self):
        """Synthetic layered DAG with deliberate exact ties (integer f32
        costs): kernel == ref.tropical_route_kbest_ref element-wise."""
        rng = np.random.default_rng(3)
        P, L, K, R = 24, 6, 3, 4
        starts = (rng.integers(0, 3, P) * 2).astype(np.int32)
        ends = np.minimum(starts + 2, L).astype(np.int32)
        costs = rng.integers(1, 8, (R, P)).astype(np.float32)  # many ties
        costs[rng.random((R, P)) < 0.2] = 3.0e38
        rd, re, rr = ref.tropical_route_kbest_ref(starts, ends, costs, L, K)
        kd, ke, kr = tropical_route_kbest(
            jnp.asarray(starts), jnp.asarray(ends), jnp.asarray(costs),
            total_layers=L, k_best=K, blk_r=4, interpret=True)
        np.testing.assert_array_equal(np.asarray(kd), rd)
        np.testing.assert_array_equal(np.asarray(ke), re)
        np.testing.assert_array_equal(np.asarray(kr), rr)

    @pytest.mark.parametrize("use_kernel", [False, True])
    def test_infeasible_rows(self, gcfg, use_kernel):
        """Floors above every trust value: INF costs, no chains, and the
        numpy planner agrees the problem is infeasible."""
        anchor = build_layered_anchor(gcfg, L=12, replicas=3, seed=0,
                                      trust_range=(0.5, 0.9))
        t = anchor.snapshot(0.0)
        planner = RoutePlanner(12, k_best=4)
        taus = np.array([0.99, 0.95])
        hops, costs = route_batched_kbest(
            t, 12, gcfg, taus, k_max=12, k_best=4, planner=planner,
            use_kernel=use_kernel, interpret=use_kernel)
        assert np.all(costs >= INF)
        assert np.all(hops == -1)
        chains, _ = _numpy_kbest_chains(planner, t, gcfg, 0.99, 4)
        assert chains == []

    def test_partial_k_feasible(self, gcfg):
        """Fewer than K distinct chains exist: both backends emit the same
        truncated set, INF-padded on device."""
        cfg = gcfg
        anchor = build_layered_anchor(cfg, L=6, segments=(3,), replicas=2,
                                      seed=0)
        t = anchor.snapshot(0.0)    # 2x2 = 4 distinct chains < k=8
        planner = RoutePlanner(6, k_best=8)
        chains, costs = _numpy_kbest_chains(planner, t, cfg, 0.0, 8)
        assert len(chains) == 4
        dev = _device_kbest_chains(t, cfg, np.array([0.0]), 8, 6, planner,
                                   use_kernel=False)
        assert dev[0][0] == chains


class TestDegenerateBatches:
    def test_kernel_empty_batch_regression(self):
        """R == 0 used to divide by zero in the grid computation; it must
        return empty (0, L+1) outputs instead."""
        starts = jnp.zeros((8,), jnp.int32)
        ends = jnp.full((8,), 3, jnp.int32)
        costs = jnp.zeros((0, 8), jnp.float32)
        d, p = tropical_route(starts, ends, costs, total_layers=6)
        assert d.shape == (0, 7) and p.shape == (0, 7)
        dk, ek, rk = tropical_route_kbest(starts, ends, costs,
                                          total_layers=6, k_best=4)
        assert dk.shape == (0, 7, 4) and ek.shape == (0, 7, 4)
        assert rk.shape == (0, 7, 4)

    def test_route_batched_empty(self, gcfg, layered_anchor):
        t = layered_anchor.snapshot(0.0)
        ids, costs = route_batched(t, 12, gcfg, np.zeros((0,)), k_max=12)
        assert ids.shape == (0, 12) and costs.shape == (0,)
        hops, ck = route_batched_kbest(t, 12, gcfg, np.zeros((0,)),
                                       k_max=12, k_best=4)
        assert hops.shape == (0, 4, 12) and ck.shape == (0, 4)

    def test_backtrack_kbest_shapes(self, gcfg, layered_anchor):
        t = layered_anchor.snapshot(0.0)
        taus = np.array([0.0])
        costs = effective_costs(jnp.asarray(t.latency_ms, jnp.float32),
                                jnp.asarray(t.trust, jnp.float32),
                                jnp.asarray(t.alive),
                                jnp.asarray(taus, jnp.float32),
                                gcfg.request_timeout_ms)
        starts = jnp.asarray(t.layer_start, jnp.int32)
        ends = jnp.asarray(t.layer_end, jnp.int32)
        dk, pe, pr = layered_dp_kbest(starts, ends, costs, total_layers=12,
                                      k_best=2)
        hops = backtrack_kbest(starts, pe, pr, total_layers=12, k_max=12)
        assert hops.shape == (1, 2, 12)


class TestDevicePlansDriveFailover:
    def test_device_plan_splices_with_zero_searches(self, gcfg):
        """A plan built by the batched device path must recover a
        mid-chain failure from its precomputed alternates: no planner
        solve, no fresh search."""
        anchor = build_layered_anchor(gcfg, L=6, segments=(3,), replicas=3,
                                      seed=0, trust_range=(0.95, 1.0))
        t = anchor.snapshot(0.0)
        planner = RoutePlanner(6, k_best=6)
        plans = plan_batched(t, 6, gcfg, np.array([0.0]), planner=planner,
                             k_best=6, backend="jnp")
        plan = plans[0]
        assert plan.feasible and len(plan.chain_ids(0)) == 2
        solves_before = planner.stats["solves"]
        failed = plan.chain_ids(0)[1]

        def hop(pid, k, payload):
            return payload, 10.0, pid != failed

        ex = ChainExecutor(gcfg, hop)
        report, _ = ex.execute(plan.chain_ids(0), t, plan=plan)
        assert report.success and report.repaired
        assert ex.plan_repairs == 1                      # from the plan...
        assert planner.stats["solves"] == solves_before  # ...zero searches
        assert failed not in report.chain

    @pytest.mark.parametrize("backend", ["numpy", "jnp", "pallas"])
    def test_all_backends_build_identical_plans(self, gcfg, backend):
        """plan_batched output == planner.plan output (same chains, same
        alternate order) for matching floors, on every backend."""
        anchor = build_layered_anchor(gcfg, L=12, replicas=4, seed=2)
        t = anchor.snapshot(0.0)
        t.latency_ms[:] = np.round(t.latency_ms)
        t.trust[:] = np.round(t.trust * 8) / 8
        planner = RoutePlanner(12, k_best=4)
        for tau in (0.0, 0.7):
            w = t.latency_ms + (1.0 - t.trust) * gcfg.request_timeout_ms
            mask = t.alive & (t.trust >= tau)
            p_np = planner.plan(t, w, mask, k=4)
            p_dev = plan_batched(t, 12, gcfg, np.array([tau]),
                                 planner=planner, k_best=4,
                                 backend=backend,
                                 interpret=(backend == "pallas"))[0]
            assert p_dev.chain_rows == p_np.chain_rows
            for a, b in zip(p_dev.costs, p_np.costs):
                assert a == pytest.approx(b, rel=1e-5)

    def test_batched_numpy_solver_matches_per_request(self, gcfg):
        """solve_kbest_batched row r == solve_kbest with mask row r,
        bit-for-bit (same float64 arithmetic, same tie-break)."""
        anchor = build_layered_anchor(gcfg, L=12, replicas=5, seed=4)
        t = anchor.snapshot(0.0)
        planner = RoutePlanner(12, k_best=4)
        w = t.latency_ms + (1.0 - t.trust) * gcfg.request_timeout_ms
        taus = np.array([0.0, 0.6, 0.8, 0.99])
        masks = t.alive[None, :] & (t.trust[None, :] >= taus[:, None])
        chains_b, costs_b = planner.solve_kbest_batched(t, w, masks, k=4)
        for r, tau in enumerate(taus):
            chains, costs = planner.solve_kbest(t, w, masks[r], k=4)
            assert chains_b[r] == chains
            assert costs_b[r] == costs
