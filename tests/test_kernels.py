"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
all in interpret mode (CPU container; TPU is the deploy target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv6_chunk import wkv6_chunked
from repro.kernels.ssd_chunk import ssd_chunked
from repro.kernels.tropical_route import tropical_route

KEY = jax.random.PRNGKey(42)


def tol(dt):
    return 2e-2 if dt == jnp.bfloat16 else 2e-4


@pytest.mark.parametrize("B,S,Hq,Hkv,D", [
    (2, 64, 4, 2, 32),
    (1, 128, 8, 2, 64),
    (2, 96, 3, 1, 16),     # MQA, ragged heads
    (1, 256, 2, 2, 128),   # MHA, MXU-width head
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(B, S, Hq, Hkv, D, dtype, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, blk_q=32, blk_k=32,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), atol=tol(dtype))


@pytest.mark.parametrize("B,S,Hq,Hkv,D", [
    (2, 64, 4, 2, 32),
    (3, 256, 8, 1, 64),
    (1, 128, 5, 5, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, S, Hq, Hkv, D, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    ck = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    cv = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    kv_len = jax.random.randint(ks[3], (B,), 1, S + 1)
    out = decode_attention(q, ck, cv, kv_len, blk_k=32, interpret=True)
    want = ref.decode_attention_ref(q, ck, cv, kv_len)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), atol=tol(dtype))


@pytest.mark.parametrize("R,P,L,segs", [
    (8, 64, 12, (3, 4, 6)),
    (16, 128, 36, (3, 6, 9)),
    (8, 32, 8, (2, 4)),
])
def test_tropical_route(R, P, L, segs):
    rng = np.random.default_rng(0)
    starts, ends = [], []
    for _ in range(P):
        s = int(rng.choice(segs))
        st = int(rng.integers(0, L // s)) * s
        starts.append(st)
        ends.append(min(st + s, L))
    starts = np.array(starts, np.int32)
    ends = np.array(ends, np.int32)
    costs = rng.uniform(1, 500, (R, P)).astype(np.float32)
    costs[rng.random((R, P)) < 0.3] = 3.0e38
    dist, pred = tropical_route(jnp.array(starts), jnp.array(ends),
                                jnp.array(costs), total_layers=L,
                                blk_r=8, interpret=True)
    rd, rp = ref.tropical_route_ref(starts, ends, costs, L)
    finite = np.isfinite(rd) & (rd < 1e38)
    np.testing.assert_allclose(np.where(finite, np.asarray(dist), 0),
                               np.where(finite, rd, 0), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(pred), rp)


@pytest.mark.parametrize("B,S,H,K,chunk", [
    (2, 64, 2, 16, 16),
    (1, 128, 4, 32, 32),
    (2, 96, 3, 8, 32),
])
def test_wkv6_chunked(B, S, H, K, chunk):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, S, H, K))
    k = jax.random.normal(ks[1], (B, S, H, K))
    v = jax.random.normal(ks[2], (B, S, H, K))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, K)) - 2.0)
    u = 0.3 * jax.random.normal(ks[4], (H, K))
    s0 = jnp.zeros((B, H, K, K))
    y, s = wkv6_chunked(r, k, v, lw, u, s0, chunk=chunk, interpret=True)
    yr, sr = ref.wkv6_ref(r, k, v, lw, u, s0)
    np.testing.assert_allclose(y, yr, atol=5e-4)
    np.testing.assert_allclose(s, sr, atol=5e-4)


def test_wkv6_nonzero_initial_state():
    ks = jax.random.split(KEY, 6)
    B, S, H, K = 1, 32, 2, 8
    r, k, v = (jax.random.normal(ks[i], (B, S, H, K)) for i in range(3))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, K)) - 2.0)
    u = 0.1 * jax.random.normal(ks[4], (H, K))
    s0 = jax.random.normal(ks[5], (B, H, K, K))
    y, s = wkv6_chunked(r, k, v, lw, u, s0, chunk=8, interpret=True)
    yr, sr = ref.wkv6_ref(r, k, v, lw, u, s0)
    np.testing.assert_allclose(y, yr, atol=5e-4)
    np.testing.assert_allclose(s, sr, atol=5e-4)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 64, 2, 16, 8, 16),
    (1, 128, 4, 32, 16, 32),
])
def test_ssd_chunked(B, S, H, P, N, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    la = -jnp.exp(jax.random.normal(ks[2], (B, S, H)) - 1.0) * dt
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    h0 = jnp.zeros((B, H, N, P))
    y, h = ssd_chunked(x, dt, la, Bm, Cm, h0, chunk=chunk, interpret=True)
    yr, hr = ref.ssd_ref(x, dt, la, Bm, Cm, h0)
    np.testing.assert_allclose(y, yr, atol=5e-4)
    np.testing.assert_allclose(h, hr, atol=5e-4)


def test_wkv6_strong_decay_no_overflow():
    """Overflow-safety: decay near 0 (log-decay very negative)."""
    B, S, H, K = 1, 64, 1, 8
    ks = jax.random.split(KEY, 3)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, K)) for i in range(3))
    lw = jnp.full((B, S, H, K), -20.0)       # w = e^-20: brutal decay
    u = jnp.zeros((H, K))
    s0 = jnp.zeros((B, H, K, K))
    y, s = wkv6_chunked(r, k, v, lw, u, s0, chunk=32, interpret=True)
    assert bool(jnp.all(jnp.isfinite(y))) and bool(jnp.all(jnp.isfinite(s)))
    yr, _ = ref.wkv6_ref(r, k, v, lw, u, s0)
    np.testing.assert_allclose(y, yr, atol=5e-4)
