"""serving/kv_cache.py: capacity helpers and the KV locality tracker."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import GTRACConfig
from repro.serving.kv_cache import (KVLocalityTracker, cache_bytes,
                                    grow_cache, make_cache)

from conftest import build_layered_anchor


class TestCacheHelpers:
    def test_cache_bytes_matches_hand_computed_footprint(self):
        cfg = get_config("gpt2-large").reduced(num_layers=2)
        B, cap = 3, 17
        kv = (cfg.num_layers * B * cap * cfg.num_kv_heads * cfg.head_dim
              * np.dtype(cfg.activation_dtype).itemsize)
        want = 2 * kv + np.dtype(np.int32).itemsize   # k + v + index scalar
        assert cache_bytes(cfg, B, cap) == want
        # and it is exactly the bytes of a concrete cache
        concrete = make_cache(cfg, B, cap)
        assert cache_bytes(cfg, B, cap) == sum(
            leaf.size * leaf.dtype.itemsize
            for leaf in jax.tree_util.tree_leaves(concrete)
            if hasattr(leaf, "dtype"))

    def test_grow_cache_zero_pads_and_preserves(self):
        cfg = get_config("gpt2-large").reduced(num_layers=2)
        cache = make_cache(cfg, 1, 4)
        cache["k"] = cache["k"] + 1.0        # nonzero payload to preserve
        cache["index"] = jnp.asarray(3, jnp.int32)
        grown = grow_cache(cache, 7)
        assert grown["k"].shape[2] == 7 and grown["v"].shape[2] == 7
        np.testing.assert_array_equal(np.asarray(grown["k"][:, :, :4]),
                                      np.asarray(cache["k"]))
        assert float(jnp.abs(grown["k"][:, :, 4:]).sum()) == 0.0
        # non-KV leaves pass through untouched
        assert int(grown["index"]) == 3
        # shrinking is a no-op, never a truncation
        same = grow_cache(cache, 2)
        assert same["k"].shape == cache["k"].shape


class TestKVLocalityTracker:
    def test_record_and_queries(self):
        kv = KVLocalityTracker()
        kv.record(7, [1, 2, 3], pos=8)
        assert kv.warm_pos(7, 2) == 8
        assert kv.warm_pos(7, 9) == 0          # cold peer
        assert kv.warm_pos(8, 2) == 0          # cold stream
        assert sorted(kv.warm_ids(7)) == [1, 2, 3]
        assert kv.warm_chain(7) == (1, 2, 3)
        assert kv.chain_warm(7, [1, 2, 3], 8)
        assert not kv.chain_warm(7, [1, 2, 3], 9)   # beyond recorded pos
        assert not kv.chain_warm(7, [1, 2, 4], 8)   # cold hop in chain
        kv.record(7, [1, 2, 4], pos=9)              # rerouted chain
        assert kv.warm_pos(7, 3) == 8               # old hop keeps its KV
        assert kv.warm_chain(7) == (1, 2, 4)
        kv.drop_stream(7)
        assert kv.warm_ids(7) == [] and kv.warm_chain(7) is None

    def test_invalidate_peer_drops_across_streams(self):
        kv = KVLocalityTracker()
        kv.record(1, [10, 11], pos=4)
        kv.record(2, [10, 12], pos=6)
        assert kv.invalidate_peer(10) == 2
        assert kv.warm_pos(1, 10) == 0 and kv.warm_pos(2, 10) == 0
        assert kv.warm_pos(1, 11) == 4
        assert kv.invalidated_peers == 2

    def test_validate_drops_expired_and_distrusted(self, gcfg):
        anchor = build_layered_anchor(gcfg, L=4, segments=(2,), replicas=2,
                                      trust_range=(0.97, 1.0))
        table = anchor.snapshot(0.0)
        pids = [int(p) for p in table.peer_ids]
        kv = KVLocalityTracker()
        kv.record(1, pids[:2], pos=5)
        assert kv.validate(table, gcfg.trust_floor) == 0
        assert kv.warm_chain(1) == tuple(pids[:2])
        # trust collapse below the floor invalidates that peer's KV entry
        anchor.set_trust(pids[0], gcfg.trust_floor - 0.1)
        t2 = anchor.snapshot(0.0)
        assert kv.validate(t2, gcfg.trust_floor) == 1
        assert kv.warm_pos(1, pids[0]) == 0
        assert kv.warm_pos(1, pids[1]) == 5     # survivor untouched
        assert kv.warm_chain(1) is None          # chain no longer whole
        assert kv.invalidated_streams == 1
        # same snapshot object: version-keyed validate is a no-op probe
        assert kv.validate(t2, gcfg.trust_floor) == 0

    def test_validate_handles_peer_removal(self, gcfg):
        gcfg = GTRACConfig(ttl_expire_factor=1.0)
        anchor = build_layered_anchor(gcfg, L=4, segments=(2,), replicas=2)
        table = anchor.snapshot(0.0)
        victim = int(table.peer_ids[0])
        kv = KVLocalityTracker()
        kv.record(3, [victim], pos=2)
        # no heartbeats: the sweep TTL-expires every peer out of the registry
        anchor.sweep(now=1e6)
        gone = anchor.snapshot(1e6)
        assert kv.validate(gone, gcfg.trust_floor) == 1
        assert kv.warm_ids(3) == []
