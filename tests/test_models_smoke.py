"""Per-architecture smoke tests: reduced same-family config, one forward /
train step on CPU, output shapes + finiteness; decode-vs-forward
consistency; chunked-vs-recurrent equivalence for the SSM families."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models.api import build_model

KEY = jax.random.PRNGKey(0)


def make_inputs(cfg, B=2, S=32, key=KEY):
    tokens = jax.random.randint(key, (B, S), 1, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    pf = {"tokens": tokens}
    if cfg.family == "audio":
        frames = jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
        batch["frames"] = frames
        pf["frames"] = frames
    if cfg.family == "vlm":
        ve = jax.random.normal(key, (B, 8, cfg.d_model), jnp.bfloat16)
        pos = jnp.broadcast_to(jnp.arange(S + 8)[None, None, :],
                               (3, B, S + 8)).astype(jnp.int32)
        batch.update(vision_embeds=ve, positions=pos)
        pf.update(prefix_embeds=ve, positions=pos)
    return batch, pf


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_loss_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 32
    batch, pf = make_inputs(cfg, B, S)

    loss = model.loss_fn(params, batch)
    assert loss.shape == () and bool(jnp.isfinite(loss))

    logits, cache = model.prefill(params, **pf)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    tok = batch["tokens"][:, :1]
    logits2, cache = model.decode_step(params, tok, cache)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))
    prefix = 8 if cfg.family == "vlm" else 0   # vision stub extends the seq
    assert int(cache["index"]) == S + prefix + 1


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step_no_nans(arch):
    from repro.configs.base import TrainConfig
    from repro.trainer import optimizer as opt
    from repro.trainer.train_loop import make_train_step

    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    batch, _ = make_inputs(cfg)
    step = jax.jit(make_train_step(model, TrainConfig(warmup_steps=1,
                                                      total_steps=4)))
    opt_state = opt.init(params)
    p1, o1, m1 = step(params, opt_state, batch)
    p2, o2, m2 = step(p1, o1, batch)
    assert bool(jnp.isfinite(m1["loss"])) and bool(jnp.isfinite(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"]) + 1.0  # not diverging
    for leaf in jax.tree_util.tree_leaves(p2):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "granite-34b",
                                  "qwen3-moe-30b-a3b", "rwkv6-1.6b",
                                  "zamba2-2.7b", "whisper-large-v3"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the full-forward logits."""
    cfg = get_config(arch).reduced(activation_dtype="float32",
                                   moe_capacity_factor=4.0)
    model = build_model(cfg)
    params = model.init(KEY)
    B, S, T = 1, 16, 6
    batch, pf = make_inputs(cfg, B, S + T)
    tokens = batch["tokens"]
    # full forward logits at positions S-1 .. S+T-2 == prefill+decode chain
    pf_full = dict(pf)
    pf_full["tokens"] = tokens
    logits_full, _ = model.prefill(params, **pf_full)  # last position only

    pf_prefix = dict(pf)
    pf_prefix["tokens"] = tokens[:, :S]
    if cfg.family == "audio":
        pf_prefix["frames"] = pf["frames"]
    if cfg.family == "vlm":
        pf_prefix["positions"] = pf["positions"][:, :, :S + 8]
    logits, cache = model.prefill(params, **pf_prefix,
                                  capacity=S + T + 4)
    for t in range(T):
        logits, cache = model.decode_step(params, tokens[:, S + t:S + t + 1],
                                          cache)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(logits_full, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_rwkv6_chunked_equals_recurrent():
    from repro.models import rwkv6 as R
    cfg = get_config("rwkv6-1.6b").reduced(d_model=64, rwkv_head_dim=16,
                                           d_ff=128,
                                           activation_dtype="float32")
    params = R.init(KEY, cfg)
    B, S = 2, 16
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    x_full, _ = R.forward_hidden(cfg, params, tokens)
    st = None
    outs = []
    for t in range(S):
        x1, st = R.forward_hidden(cfg, params, tokens[:, t:t + 1], st,
                                  single_step=True)
        outs.append(x1)
    np.testing.assert_allclose(np.asarray(x_full),
                               np.asarray(jnp.concatenate(outs, 1)),
                               atol=1e-4)


def test_mrope_degenerates_to_rope_for_text():
    """Text-only M-RoPE (equal position streams) == plain RoPE."""
    from repro.models.rope import positional_angles
    cfg = get_config("qwen2-vl-7b").reduced()
    cfg_rope = dataclasses.replace(cfg, pos_type="rope")
    B, S = 2, 16
    pos = jnp.arange(S)[None, :].repeat(B, 0)
    a1 = positional_angles(cfg, pos)              # mrope, text-only
    a2 = positional_angles(cfg_rope, pos)         # plain rope
    # same multiset of frequencies; compare sorted spectra per position
    np.testing.assert_allclose(np.sort(np.asarray(a1), -1),
                               np.sort(np.asarray(a2), -1), rtol=1e-6)


def test_moe_capacity_drop_is_bounded():
    """With capacity_factor >= k coverage, no token drops; gates sum to 1."""
    from repro.models import moe as M
    cfg = get_config("qwen3-moe-30b-a3b").reduced(
        num_experts=8, experts_per_token=2, moe_capacity_factor=8.0)
    p = M.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32)
    y, aux = M.apply_moe(cfg, p, x, return_aux=True)
    assert y.shape == x.shape and bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) >= 1.0 - 1e-3  # E * sum f_e P_e >= 1 by Cauchy-Schwarz


def test_moe_matches_dense_gather_oracle():
    """Sorted-scatter dispatch == per-token gather-compute oracle."""
    from repro.models import moe as M
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced(
        num_experts=4, experts_per_token=2, moe_capacity_factor=16.0)
    p = M.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (1, 8, cfg.d_model), jnp.float32)
    y = M.apply_moe(cfg, p, x)
    # oracle: explicit per-token expert compute
    xf = x.reshape(-1, cfg.d_model)
    gates, idx, _ = M.route_topk(cfg, p, xf)
    want = jnp.zeros_like(xf)
    for t in range(xf.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(cfg.experts_per_token):
            e = int(idx[t, j])
            h = xf[t] @ p["wi"][e]
            h = jax.nn.silu(h) * (xf[t] @ p["wg"][e])
            acc = acc + gates[t, j] * (h @ p["wo"][e])
        want = want.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(want), atol=1e-4)
