"""Observability plane (repro.obs): tracer/metrics units, exact
FakeClock span trees across the rpc boundary, executor failover/hedge
markers, export round-trips, and the traced serving integration with
its TTFT decomposition identity."""
import json

import numpy as np
import pytest

from repro.configs.base import GTRACConfig
from repro.control_plane import (
    FakeClock,
    LoopbackTransport,
    RpcChannel,
    RpcPolicy,
    RpcTimeout,
    ShardHost,
)
from repro.core.hedging import HedgedChainExecutor
from repro.obs.export import (
    export_chrome,
    export_jsonl,
    load_jsonl,
    validate_jsonl,
)
from repro.obs.metrics import (
    Histogram,
    MetricsRegistry,
    percentiles,
)
from repro.obs.report import itl_breakdown, ttft_breakdown
from repro.obs.trace import NOOP_TRACER, TraceBuffer, Tracer


@pytest.fixture
def gcfg():
    return GTRACConfig()


# ---------------------------------------------------------------------------
# metrics: the shared percentile helper + registry views
# ---------------------------------------------------------------------------


class TestMetrics:
    def test_percentiles_empty_sentinel(self):
        assert percentiles([], (50, 99)) == (-1.0, -1.0)

    def test_percentiles_values(self):
        xs = list(range(1, 101))
        p50, p90 = percentiles(xs, (50, 90))
        assert p50 == pytest.approx(np.percentile(xs, 50))
        assert p90 == pytest.approx(np.percentile(xs, 90))

    def test_counter_gauge(self):
        reg = MetricsRegistry()
        reg.counter("a/hits").inc()
        reg.counter("a/hits").inc(2)      # get-or-create returns same
        reg.gauge("a/level").set(7.5)
        snap = reg.snapshot()
        assert snap["a/hits"] == 3
        assert snap["a/level"] == 7.5

    def test_histogram_buckets_and_stats(self):
        h = Histogram(uppers=(1, 10, 100))
        for v in (0.5, 5, 50, 500):
            h.observe(v)
        assert h.count == 4
        assert h.sum == pytest.approx(555.5)
        assert h.counts == [1, 1, 1, 1]   # one in overflow
        assert h.mean() == pytest.approx(555.5 / 4)
        assert h.percentile(50) == 10     # bucket upper bound
        assert h.percentile(99) == 500    # overflow reports max
        assert Histogram((1,)).percentile(50) == -1.0

    def test_expose_is_live_view(self):
        from repro.sync.relay import RelayStats
        reg = MetricsRegistry()
        rs = RelayStats()
        reg.expose("relay", rs)
        reg.derived("relay/wire_bytes", rs.seeker_wire_bytes)
        assert reg.snapshot()["relay/msgs"] == 0
        rs.msgs += 5
        rs.msg_bytes += 420
        snap = reg.snapshot()              # fresh read, no re-expose
        assert snap["relay/msgs"] == 5
        assert snap["relay/wire_bytes"] == rs.seeker_wire_bytes()
        assert isinstance(snap["relay/msgs"], int)


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------


class TestTracer:
    def _tracer(self, t0=0.0):
        state = {"t": t0}
        tr = Tracer(TraceBuffer(), clock=lambda: state["t"])
        return tr, state

    def test_lexical_nesting(self):
        tr, st = self._tracer()
        with tr.span("outer"):
            st["t"] = 1.0
            with tr.span("inner"):
                st["t"] = 3.0
            st["t"] = 5.0
        spans = {s.name: s for s in tr.sink.spans}
        assert spans["inner"].parent_id == spans["outer"].span_id
        assert spans["outer"].parent_id is None
        assert spans["inner"].dur_s == pytest.approx(2.0)
        assert spans["outer"].dur_s == pytest.approx(5.0)

    def test_non_lexical_request_span(self):
        tr, st = self._tracer()
        req = tr.begin("request", t0=0.0, rid=1)
        with tr.span("window"):
            st["t"] = 2.0
        tr.end(req, t1=4.0, ttft_ms=123.0)
        spans = {s.name: s for s in tr.sink.spans}
        # the window was pushed while the request span was NOT on the
        # stack, so it does not become the request's child
        assert spans["window"].parent_id is None
        assert spans["request"].dur_s == pytest.approx(4.0)
        assert spans["request"].attrs["ttft_ms"] == 123.0

    def test_add_and_event_post_hoc(self):
        tr, _ = self._tracer()
        p = tr.add("step", 1.0, 2.5, rid=9)
        tr.add("hop", 1.0, 1.5, parent=p, peer=3)
        tr.event("marker", t=2.0, parent=p)
        hop = [s for s in tr.sink.spans if s.name == "hop"][0]
        mk = [s for s in tr.sink.spans if s.name == "marker"][0]
        assert hop.parent_id == p.span_id
        assert hop.dur_s == pytest.approx(0.5)
        assert mk.dur_s == 0.0 and mk.t0 == 2.0

    def test_scope_shares_ring_separate_domain(self):
        tr, _ = self._tracer()
        rpc = tr.scope("rpc", clock=lambda: 42.0)
        sp = rpc.begin("rpc.collect")
        rpc.end(sp)
        assert sp.domain == "rpc" and sp.t0 == 42.0
        assert sp in tr.sink.spans          # same buffer

    def test_buffer_eviction_counts(self):
        buf = TraceBuffer(capacity=2)
        tr = Tracer(buf, clock=lambda: 0.0)
        for i in range(5):
            tr.end(tr.begin(f"s{i}"))
        assert len(buf) == 2 and buf.dropped == 3

    def test_noop_tracer_is_inert(self):
        sp = NOOP_TRACER.begin("x", anything=1)
        assert NOOP_TRACER.span("y") is sp        # one shared object
        assert NOOP_TRACER.add("z", 0, 1) is sp
        assert not NOOP_TRACER.enabled
        with NOOP_TRACER.span("w"):
            pass                                   # context form works


# ---------------------------------------------------------------------------
# exact rpc span trees on FakeClock (cross-process stamps included)
# ---------------------------------------------------------------------------


class _DropTransport(LoopbackTransport):
    """Loopback that eats the next n replies AFTER servicing them."""

    def __init__(self, host):
        super().__init__(host)
        self.mute = False
        self.drop_next = 0

    def post(self, msg):
        if self.mute:
            return
        super().post(msg)
        if self.drop_next > 0 and self._out:
            self._out.pop()
            self.drop_next -= 1


class TestRpcSpanTree:
    POL = RpcPolicy(timeout_s=1.0, retries=2, backoff_base_s=0.05,
                    backoff_factor=2.0)

    def _channel(self, gcfg, svc_ticks=None):
        clock = FakeClock()
        host = ShardHost(gcfg, 0, svc_clock=(
            (lambda it: (lambda: next(it)))(iter(svc_ticks))
            if svc_ticks is not None else None))
        tr = _DropTransport(host)
        ch = RpcChannel(tr, self.POL, clock)
        ch.tracer = Tracer(TraceBuffer(), clock=clock.monotonic,
                           domain="rpc")
        return ch, tr, clock

    def test_retry_with_backoff_exact_tree(self, gcfg):
        """Lost reply -> one backoff, one retry answered from the worker
        dedup cache carrying the ORIGINAL cross-process span stamp. The
        whole tree — ids, parents, t0/t1 — is exact on FakeClock."""
        ch, tr, clock = self._channel(gcfg, svc_ticks=[10.0, 10.007])
        tr.drop_next = 1
        ch.request("register", 7, 0, 2, 0.0, "", None, None, 0, None)
        assert ch.stats.rpc_retries == 1
        assert clock.sleeps == [0.05]
        spans = list(ch.tracer.sink.spans)   # completion order
        names = [s.name for s in spans]
        assert names == ["rpc.attempt", "rpc.backoff", "rpc.attempt",
                         "rpc.worker", "rpc.collect"]
        att0, bo, att1, wrk, root = spans
        assert root.parent_id is None
        assert att0.parent_id == bo.parent_id == att1.parent_id \
            == root.span_id
        assert wrk.parent_id == att1.span_id
        # FakeClock never advances inside a poll, so the failed attempt
        # is instantaneous and the backoff is the only elapsed time
        assert (att0.t0, att0.t1) == (0.0, 0.0)
        assert att0.attrs == {"attempt": 0, "ok": False, "timeout": True}
        assert (bo.t0, bo.t1) == (0.0, 0.05)
        assert (att1.t0, att1.t1) == (0.05, 0.05)
        assert att1.attrs == {"attempt": 1, "ok": True}
        # worker span: service time measured by the injected worker
        # clock (10.007 - 10.0), laid back-to-back against attempt end
        assert wrk.t1 == 0.05
        assert wrk.dur_s == pytest.approx(0.007)
        assert wrk.attrs == {"worker_span": 1}
        assert root.attrs["outcome"] == "ok"
        assert root.attrs["attempts"] == 2
        assert root.attrs["op"] == "register"
        assert (root.t0, root.t1) == (0.0, 0.05)

    def test_timeout_exhaustion_tree(self, gcfg):
        """Dead-air worker: retries+1 zero-length attempts separated by
        exact exponential backoffs; the root records the outcome."""
        ch, tr, clock = self._channel(gcfg)
        tr.mute = True
        with pytest.raises(RpcTimeout):
            ch.request("ping")
        spans = list(ch.tracer.sink.spans)
        names = [s.name for s in spans]
        assert names == ["rpc.attempt", "rpc.backoff", "rpc.attempt",
                         "rpc.backoff", "rpc.attempt", "rpc.collect"]
        backoffs = [s for s in spans if s.name == "rpc.backoff"]
        assert [pytest.approx(b.dur_s) for b in backoffs] == [0.05, 0.10]
        assert backoffs[1].t0 == pytest.approx(0.05)
        root = spans[-1]
        assert root.attrs["outcome"] == "timeout"
        assert root.attrs["attempts"] == 3
        assert root.t1 == pytest.approx(0.15)
        assert all(s.name != "rpc.worker" for s in spans)

    def test_untraced_channel_no_spans(self, gcfg):
        clock = FakeClock()
        ch = RpcChannel(LoopbackTransport(ShardHost(gcfg, 0)), self.POL,
                        clock)
        ch.request("ping")
        assert ch.tracer is NOOP_TRACER


# ---------------------------------------------------------------------------
# executor markers: failover splice + hedged race
# ---------------------------------------------------------------------------


def _stage_table(gcfg, latencies):
    from repro.core.registry import AnchorRegistry
    a = AnchorRegistry(gcfg)
    for pid, lat in enumerate(latencies):
        a.register(pid, 0, 3, now=0.0, latency_ms=lat)
        a.heartbeat(pid, 0.0)
    a.register(99, 3, 6, now=0.0, latency_ms=50.0)
    a.heartbeat(99, 0.0)
    return a.snapshot(0.0)


class TestExecutorMarkers:
    def test_failover_splice_event(self, gcfg):
        from repro.core.executor import ChainExecutor
        t = _stage_table(gcfg, [100.0, 100.0])

        def hop(pid, k, payload):
            return payload, 150.0, pid != 0     # peer 0 fails

        ex = ChainExecutor(gcfg, hop)
        ex.tracer = Tracer(TraceBuffer(), clock=lambda: 7.0)
        report, _ = ex.execute([0, 99], t)
        assert report.success and report.repaired
        ev = [s for s in ex.tracer.sink.spans
              if s.name == "failover.splice"]
        assert len(ev) == 1
        assert ev[0].cat == "failover" and ev[0].dur_s == 0.0
        assert ev[0].t0 == 7.0
        assert ev[0].attrs["failed_peer"] == 0
        assert ev[0].attrs["repair_peer"] == report.repair_peer == 1
        assert ev[0].attrs["via"] == "search"    # no RoutePlan given
        assert ev[0].attrs["stage"] == 0

    def test_hedge_fired_and_won_events(self, gcfg):
        t = _stage_table(gcfg, [100.0, 100.0])
        lat = {0: 1000.0, 1: 80.0, 99: 50.0}     # peer 0 straggles

        def hop(pid, k, payload):
            return payload, lat[pid], True

        ex = HedgedChainExecutor(gcfg, hop, quantile_factor=2.0)
        ex.tracer = Tracer(TraceBuffer(), clock=lambda: 3.0)
        report, _ = ex.execute([0, 99], t)
        assert report.success
        ev = {s.name: s for s in ex.tracer.sink.spans}
        assert set(ev) == {"hedge.fired", "hedge.won"}
        fired, won = ev["hedge.fired"], ev["hedge.won"]
        assert fired.attrs == {"stage": 0, "peer": 0, "hedge_peer": 1,
                               "trigger_ms": 200.0}
        # winner total = trigger(200) + backup(80); saved = 1000 - 280
        assert won.attrs["saved_ms"] == pytest.approx(720.0)
        assert won.attrs["hedge_peer"] == 1

    def test_no_hedge_no_events(self, gcfg):
        t = _stage_table(gcfg, [100.0, 100.0])

        def hop(pid, k, payload):
            return payload, 90.0, True

        ex = HedgedChainExecutor(gcfg, hop)
        ex.tracer = Tracer(TraceBuffer(), clock=lambda: 0.0)
        report, _ = ex.execute([0, 99], t)
        assert report.success
        assert len(ex.tracer.sink.spans) == 0


# ---------------------------------------------------------------------------
# export: jsonl round-trip, schema validation, chrome events
# ---------------------------------------------------------------------------


def _demo_buffer():
    st = {"t": 0.0}
    tr = Tracer(TraceBuffer(), clock=lambda: st["t"], domain="serve")
    req = tr.begin("request", cat="request", t0=0.0, rid=1)
    tr.add("decode.step", 0.0, 0.25, cat="decode", parent=req, rid=1,
           emitted=True, first_token=True)
    tr.scope("rpc", clock=lambda: 9.0).end(
        tr.scope("rpc").begin("rpc.collect", cat="rpc", t0=9.0), t1=9.5)
    st["t"] = 0.25
    tr.end(req, ttft_ms=250.0)
    return tr.sink


class TestExport:
    def test_jsonl_round_trip_and_validate(self, tmp_path):
        buf = _demo_buffer()
        path = str(tmp_path / "t.jsonl")
        export_jsonl(buf, path)
        n, errors = validate_jsonl(path)
        assert n == len(buf) and errors == []
        rows = load_jsonl(path)
        by_name = {r["name"]: r for r in rows}
        assert by_name["decode.step"]["parent"] == \
            by_name["request"]["id"]
        assert by_name["decode.step"]["dur_ms"] == pytest.approx(250.0)
        assert by_name["request"]["attrs"]["ttft_ms"] == 250.0
        assert by_name["rpc.collect"]["domain"] == "rpc"

    def test_validator_catches_corruption(self, tmp_path):
        buf = _demo_buffer()
        path = str(tmp_path / "bad.jsonl")
        export_jsonl(buf, path)
        rows = [json.loads(line) for line in open(path)]
        rows[0]["t1"] = rows[0]["t0"] - 1.0       # negative duration
        del rows[1]["name"]                       # missing key
        with open(path, "w") as f:
            for r in rows:
                f.write(json.dumps(r) + "\n")
        _, errors = validate_jsonl(path)
        assert len(errors) >= 2

    def test_chrome_export_structure(self, tmp_path):
        buf = _demo_buffer()
        path = str(tmp_path / "t.trace.json")
        export_chrome(buf, path)
        doc = json.load(open(path))
        evs = doc["traceEvents"]
        pids = {e["pid"] for e in evs if e["ph"] == "X"}
        assert len(pids) == 2                     # serve + rpc domains
        step = [e for e in evs if e.get("name") == "decode.step"][0]
        assert step["dur"] == pytest.approx(250.0 * 1e3)  # microseconds
        assert any(e["ph"] == "M" for e in evs)   # process_name metadata


# ---------------------------------------------------------------------------
# report: decomposition identities on synthetic spans
# ---------------------------------------------------------------------------


class TestReport:
    def test_ttft_breakdown_sums(self):
        tr = Tracer(TraceBuffer(), clock=lambda: 0.0, domain="serve")
        req = tr.begin("request", cat="request", t0=0.0, rid=5)
        tr.add("queue.wait", 0.0, 0.1, cat="serve", parent=req)
        c = tr.add("prefill.chunk", 0.1, 0.3, cat="prefill", parent=req,
                   ok=True)
        tr.add("hop", 0.1, 0.3, cat="exec", parent=c, peer=1, ok=True)
        tr.add("prefill.stall", 0.3, 0.35, cat="prefill", parent=req)
        s = tr.add("decode.step", 0.35, 0.5, cat="decode", parent=req,
                   rid=5, emitted=True, first_token=True)
        tr.add("hop", 0.35, 0.45, cat="exec", parent=s, peer=2, ok=False)
        tr.add("hop", 0.45, 0.5, cat="exec", parent=s, peer=3, ok=True)
        tr.end(req, t1=0.5, ttft_ms=500.0, stale_rounds_max=2)
        (row,) = ttft_breakdown(tr.sink)
        assert row["rid"] == 5 and row["complete"]
        assert row["queue_wait_ms"] == pytest.approx(100.0)
        assert row["prefill_ms"] == pytest.approx(200.0)
        assert row["prefill_stall_ms"] == pytest.approx(50.0)
        assert row["decode_ms"] == pytest.approx(150.0)
        assert row["failover_ms"] == pytest.approx(100.0)  # failed hop
        assert row["stale_rounds_max"] == 2
        assert row["ttft_sum_ms"] == pytest.approx(row["measured_ttft_ms"])

    def test_itl_breakdown_exec_plus_drag(self):
        tr = Tracer(TraceBuffer(), clock=lambda: 0.0, domain="serve")
        req = tr.begin("request", cat="request", t0=0.0, rid=1)
        tr.add("decode.step", 0.0, 0.1, parent=req, cat="decode", rid=1,
               emitted=True, first_token=True, drag_ms=100.0)
        tr.add("decode.step", 0.2, 0.25, parent=req, cat="decode", rid=1,
               emitted=True, first_token=False, drag_ms=0.0)
        tr.end(req, t1=0.25, ttft_ms=100.0)
        out = itl_breakdown(tr.sink)
        assert out["n"] == 1
        # ITL = own exec (50ms) + PREVIOUS step's window drag (100ms)
        assert out["itl_p50_ms"] == pytest.approx(150.0)
        assert out["exec_p50_ms"] == pytest.approx(50.0)
        assert out["drag_p50_ms"] == pytest.approx(100.0)


# ---------------------------------------------------------------------------
# traced serving integration (real model, sim clock)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_model():
    import jax
    from repro.configs import get_config
    from repro.models.api import build_model
    cfg = get_config("gpt2-large").reduced(num_layers=4, vocab_size=128,
                                           remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))
    return cfg, params


def _traced_server(tiny_model, **gkw):
    from repro.serving.gtrac_serve import GTRACPipelineServer
    cfg, params = tiny_model
    gcfg = GTRACConfig(trace_enabled=True, **gkw)
    return GTRACPipelineServer(cfg, params, layers_per_stage=2,
                               gcfg=gcfg, seed=3)


class TestTracedServing:
    def test_ttft_identity_and_completion(self, tiny_model, tmp_path):
        """End-to-end: every completed stream's critical-path components
        sum EXACTLY to its measured TTFT, the exported trace passes the
        schema check, and the summary carries completion accounting."""
        from repro.serving.api import SubmitSpec
        from repro.serving.gtrac_serve import latency_summary
        srv = _traced_server(tiny_model, gossip_enabled=True,
                             relay_enabled=True, gossip_seekers=3,
                             disaggregate=True, prefill_chunk_tokens=4)
        for i in range(4):
            srv.submit(SubmitSpec(prompt=np.arange(1, 9 + 4 * i),
                                  max_new_tokens=4,
                                  arrival_time=0.01 * i))
        done = srv.run_queue()
        rows = ttft_breakdown(srv.trace)
        assert len(rows) == 4
        completed = [r for r in rows if r["complete"]]
        assert completed
        for r in completed:
            assert r["ttft_sum_ms"] == pytest.approx(
                r["measured_ttft_ms"], abs=1e-6), r
        # measured_ttft on the span tree == the stream's metrics ttft
        by_rid = {r.request_id: r for r in done}
        for r in completed:
            assert r["measured_ttft_ms"] == pytest.approx(
                by_rid[r["rid"]].metrics.ttft_ms)
        ls = latency_summary(done)
        assert ls["requests"] == 4
        assert ls["completed"] + ls["incomplete"] == 4
        assert ls["completion_rate"] == pytest.approx(
            ls["completed"] / 4)
        path = str(tmp_path / "serve.jsonl")
        export_jsonl(srv.trace, path)
        n, errors = validate_jsonl(path)
        assert n == len(srv.trace) and errors == []

    def test_stream_metrics_fill_matches_layer_stats(self, tiny_model):
        """Satellite regression: the registry-backed fill reproduces the
        exact values the old hand-written mirrors copied."""
        from repro.serving.api import SubmitSpec
        srv = _traced_server(tiny_model, gossip_enabled=True,
                             relay_enabled=True, gossip_seekers=3)
        srv.submit(SubmitSpec(prompt=np.arange(1, 9), max_new_tokens=3))
        (req,) = srv.run_queue()
        rs = srv.gossip.relay.stats
        m = req.metrics
        assert m.relay_msgs == rs.msgs + rs.summaries
        assert m.relay_bytes == rs.seeker_wire_bytes()
        assert m.relay_duplicates == rs.duplicates
        assert m.relay_digest_mismatches == rs.digest_mismatches
        assert m.relay_rejected_chains == rs.rejected_chains
        assert m.relay_quarantines == rs.quarantines
        assert isinstance(m.relay_msgs, int)
        # no process control plane wired -> fields keep their defaults
        assert m.shard_rpc_retries == 0 and m.worker_restarts == 0

    def test_disabled_tracing_is_noop(self, tiny_model):
        from repro.serving.api import SubmitSpec
        from repro.serving.gtrac_serve import GTRACPipelineServer
        cfg, params = tiny_model
        srv = GTRACPipelineServer(cfg, params, layers_per_stage=2,
                                  gcfg=GTRACConfig(), seed=3)
        assert srv.trace is None and srv.tracer is NOOP_TRACER
        srv.submit(SubmitSpec(prompt=np.arange(1, 9), max_new_tokens=2))
        (req,) = srv.run_queue()
        assert req.metrics.tokens == 2
        assert srv.router.tracer is NOOP_TRACER

    def test_generate_path_traced(self, tiny_model):
        """The per-token generate() loop also carries request/step/hop
        spans, and the first step IS the TTFT (no queue, no windows)."""
        srv = _traced_server(tiny_model)
        out, met = srv.generate(np.arange(1, 9), max_new_tokens=3,
                                request_id=77)
        assert met.tokens == 3
        (row,) = ttft_breakdown(srv.trace)
        assert row["rid"] == 77 and row["complete"]
        assert row["ttft_sum_ms"] == pytest.approx(
            row["measured_ttft_ms"], abs=1e-6)
        assert row["measured_ttft_ms"] == pytest.approx(met.ttft_ms)
        steps = [s for s in srv.trace.spans if s.name == "decode.step"]
        assert len(steps) == 3
        hops = [s for s in srv.trace.spans if s.name == "hop"]
        by_id = {s.span_id: s for s in srv.trace.spans}
        for h in hops:                       # hops tile their step
            assert by_id[h.parent_id].name == "decode.step"
        for st in steps:
            tiled = sum(h.dur_s for h in hops
                        if h.parent_id == st.span_id)
            assert tiled == pytest.approx(st.dur_s)
