"""Snapshot-versioned CSR route planner (core/planner.py).

Covers the PR's acceptance criteria: planner routes cost-identical to the
brute-force oracle (and the seed heap-Dijkstra path), K-best alternates
are valid feasible chains in nondecreasing cost order, version-keyed
caching returns the identical compiled graph / table objects while the
registry is unmutated, and mid-chain failures recover from the
precomputed plan without a fresh search.
"""
import numpy as np
import pytest

from repro.core import (
    AnchorRegistry,
    ChainExecutor,
    brute_force_route,
    gtrac_route,
    heap_dijkstra_route,
    plan_route,
)
from repro.core.hedging import HedgedChainExecutor
from repro.core.planner import RoutePlanner, compile_table
from repro.core.routing import _dijkstra_layered, enumerate_chains
from repro.core.trust import effective_cost_vec
from repro.core.types import ExecReport, HopReport

from conftest import build_layered_anchor


def snap(anchor, now=0.0):
    return anchor.snapshot(now)


# ---------------------------------------------------------------------------
# Optimality: planner == brute force == seed heap path
# ---------------------------------------------------------------------------


class TestOptimality:
    def test_cost_identical_to_brute_force(self, gcfg):
        """Planner G-TRAC == exact enumeration over the pruned graph, on
        random small testbeds (property-style sweep over seeds/floors)."""
        for seed in range(8):
            tau = [0.0, 0.6, 0.8, 0.9][seed % 4]
            anchor = build_layered_anchor(gcfg, L=9, segments=(3,),
                                          replicas=4, seed=seed,
                                          trust_range=(0.55, 1.0))
            t = snap(anchor)
            g = gtrac_route(t, 9, gcfg, tau=tau)
            # brute force over the SAME pruned feasible set
            mask = t.alive & (t.trust >= tau)
            chains = enumerate_chains(t, mask, 9)
            if not chains:
                assert not g.feasible
                continue
            costs = effective_cost_vec(t.latency_ms, t.trust,
                                       gcfg.request_timeout_ms)
            best = min(float(np.sum(costs[c])) for c in chains)
            assert g.feasible
            assert g.total_cost == pytest.approx(best)

    def test_matches_seed_heap_dijkstra(self, gcfg):
        for seed in range(6):
            anchor = build_layered_anchor(gcfg, L=12, seed=seed)
            t = snap(anchor)
            for tau in (0.0, 0.7, 0.9):
                g = gtrac_route(t, 12, gcfg, tau=tau)
                h = heap_dijkstra_route(t, 12, gcfg, tau=tau)
                assert g.feasible == h.feasible
                if g.feasible:
                    assert g.total_cost == pytest.approx(h.total_cost)

    def test_brute_force_epsilon_oracle(self, gcfg):
        """plan_route's primary equals brute_force_route when the trust
        floor implies the epsilon bound (design-guarantee regime)."""
        anchor = build_layered_anchor(gcfg, L=9, segments=(3,), replicas=5,
                                      seed=3, trust_range=(0.9, 1.0))
        t = snap(anchor)
        tau = 0.9
        r, _ = plan_route(t, 9, gcfg, tau=tau)
        bf = brute_force_route(t, 9, gcfg, epsilon=1 - tau ** 3)
        if r.feasible and bf.feasible:
            assert bf.total_cost <= r.total_cost + 1e-9

    def test_infeasible_when_all_dead(self, gcfg, layered_anchor):
        t = snap(layered_anchor)
        t.alive[:] = False
        r, plan = plan_route(t, 12, gcfg, tau=0.0)
        assert not r.feasible and not plan.feasible
        assert plan.resume_suffix(0) is None


# ---------------------------------------------------------------------------
# K-best alternates
# ---------------------------------------------------------------------------


class TestKBest:
    def _check_chain_valid(self, t, ids, L):
        pos = 0
        for pid in ids:
            i = t.index_of(pid)
            assert int(t.layer_start[i]) == pos
            assert bool(t.alive[i])
            pos = int(t.layer_end[i])
        assert pos == L

    def test_alternates_are_feasible_nondecreasing(self, gcfg):
        for seed in range(5):
            anchor = build_layered_anchor(gcfg, L=12, replicas=5, seed=seed)
            t = snap(anchor)
            r, plan = plan_route(t, 12, gcfg, tau=0.0, k=6)
            assert r.feasible
            costs = plan.costs
            assert all(costs[i] <= costs[i + 1] + 1e-9
                       for i in range(len(costs) - 1))
            seen = set()
            for i in range(plan.n_chains):
                ids = tuple(plan.chain_ids(i))
                assert ids not in seen          # distinct chains
                seen.add(ids)
                self._check_chain_valid(t, ids, 12)
                # reported cost is the true chain cost
                w = effective_cost_vec(t.latency_ms, t.trust,
                                       gcfg.request_timeout_ms)
                rows = [t.index_of(p) for p in ids]
                assert costs[i] == pytest.approx(float(np.sum(w[rows])))

    def test_kbest_second_best_is_true_second(self, gcfg):
        """Alternate #1 must match the best chain found by enumeration
        after excluding the primary."""
        anchor = build_layered_anchor(gcfg, L=6, segments=(3,), replicas=3,
                                      seed=1)
        t = snap(anchor)
        r, plan = plan_route(t, 6, gcfg, tau=0.0, k=4)
        w = effective_cost_vec(t.latency_ms, t.trust,
                               gcfg.request_timeout_ms)
        chains = enumerate_chains(t, t.alive, 6)
        all_costs = sorted(float(np.sum(w[c])) for c in chains)
        assert plan.costs[0] == pytest.approx(all_costs[0])
        if len(all_costs) > 1 and plan.n_chains > 1:
            assert plan.costs[1] == pytest.approx(all_costs[1])


# ---------------------------------------------------------------------------
# Version-keyed caching / zero-copy snapshots
# ---------------------------------------------------------------------------


class TestSnapshotCaching:
    def test_snapshot_identity_when_unmutated(self, gcfg, layered_anchor):
        t1 = layered_anchor.snapshot(0.0)
        t2 = layered_anchor.snapshot(1.0)
        assert t2 is t1                      # zero-copy: same object
        # shared object is never mutated: snapshot_time stays the capture
        # time, so other holders' views are unaffected by this call
        assert t2.snapshot_time == 0.0

    def test_compiled_graph_identity_when_unmutated(self, gcfg,
                                                    layered_anchor):
        planner = RoutePlanner(12)
        t1 = layered_anchor.snapshot(0.0)
        g1 = planner.compile(t1)
        t2 = layered_anchor.snapshot(0.5)
        g2 = planner.compile(t2)
        assert g2 is g1
        assert planner.stats["graph_compiles"] == 1
        assert planner.stats["graph_hits"] == 1

    def test_trust_update_reuses_topology(self, gcfg, layered_anchor):
        """apply_report invalidates the snapshot but NOT the compiled CSR
        graph (membership unchanged)."""
        planner = RoutePlanner(12)
        t1 = layered_anchor.snapshot(0.0)
        g1 = planner.compile(t1)
        layered_anchor.apply_report(
            ExecReport(False, [0], [HopReport(0, 5.0, False)],
                       failed_peer=0))
        t2 = layered_anchor.snapshot(0.0)
        assert t2 is not t1                  # state changed -> new table
        assert t2.trust[t2.index_of(0)] < t1.trust[t1.index_of(0)]
        g2 = planner.compile(t2)
        assert g2 is g1                      # same topology, same graph

    def test_membership_change_recompiles(self, gcfg, layered_anchor):
        planner = RoutePlanner(12)
        g1 = planner.compile(layered_anchor.snapshot(0.0))
        layered_anchor.register(999, 0, 3, now=0.0)
        layered_anchor.heartbeat(999, 0.0)
        g2 = planner.compile(layered_anchor.snapshot(0.0))
        assert g2 is not g1
        assert g2.n_peers == g1.n_peers + 1

    def test_heartbeat_expiry_bumps_version(self, gcfg, layered_anchor):
        t1 = layered_anchor.snapshot(0.0)
        v1 = t1.version
        assert t1.alive.all()
        t2 = layered_anchor.snapshot(gcfg.node_ttl_s + 1.0)  # all expired
        assert t2 is not t1
        assert t2.version > v1
        assert not t2.alive.any()

    def test_heartbeats_keep_snapshot_warm(self, gcfg, layered_anchor):
        """Steady-state heartbeat traffic must not invalidate the cached
        snapshot (the in-place mirror update path)."""
        t1 = layered_anchor.snapshot(0.0)
        for pid in list(layered_anchor.peers):
            layered_anchor.heartbeat(pid, 5.0)
        t2 = layered_anchor.snapshot(6.0)
        assert t2 is t1

    def test_plan_cache_hit_on_same_snapshot(self, gcfg, layered_anchor):
        planner = RoutePlanner(12)
        t = layered_anchor.snapshot(0.0)
        _, p1 = plan_route(t, 12, gcfg, tau=0.8, planner=planner)
        _, p2 = plan_route(t, 12, gcfg, tau=0.8, planner=planner)
        assert p2 is p1
        assert planner.stats["plan_hits"] == 1
        _, p3 = plan_route(t, 12, gcfg, tau=0.5, planner=planner)
        assert p3 is not p1                  # different floor, fresh DP

    def test_from_records_tables_still_work(self, gcfg):
        """Tables without registry versioning fall back to identity keys."""
        from repro.core.types import PeerTable, PeerRecord
        recs = [PeerRecord(i, 0, 6, 1.0, 50.0, 0.0) for i in range(3)]
        t = PeerTable.from_records(recs, 0.0, gcfg.node_ttl_s)
        assert t.version == -1
        r = gtrac_route(t, 6, gcfg, tau=0.0)
        assert r.feasible and r.hops == 1


# ---------------------------------------------------------------------------
# K-best failover: mid-chain recovery without a fresh search
# ---------------------------------------------------------------------------


class TestPlanFailover:
    def _anchor(self, gcfg, replicas=3):
        return build_layered_anchor(gcfg, L=6, segments=(3,),
                                    replicas=replicas, seed=0,
                                    trust_range=(0.95, 1.0))

    def test_executor_recovers_from_plan(self, gcfg):
        anchor = self._anchor(gcfg)
        t = anchor.snapshot(0.0)
        planner = RoutePlanner(6, k_best=6)
        r, plan = plan_route(t, 6, gcfg, tau=0.0, planner=planner)
        assert r.feasible and len(r.chain) == 2
        failed = r.chain[1]                  # mid-chain failure
        solves_before = planner.stats["solves"]

        def hop(pid, k, payload):
            return payload, 10.0, pid != failed

        ex = ChainExecutor(gcfg, hop)
        report, _ = ex.execute(r.chain, t, plan=plan)
        assert report.success
        assert report.repaired
        assert ex.plan_repairs == 1          # served from the plan...
        assert planner.stats["solves"] == solves_before  # ...no new search
        assert failed not in report.chain[1:]
        # spliced suffix is a valid continuation
        i = t.index_of(report.chain[1])
        assert int(t.layer_start[i]) == 3 and int(t.layer_end[i]) == 6

    def test_hedged_executor_recovers_from_plan(self, gcfg):
        anchor = self._anchor(gcfg)
        t = anchor.snapshot(0.0)
        planner = RoutePlanner(6, k_best=6)
        r, plan = plan_route(t, 6, gcfg, tau=0.0, planner=planner)
        failed = r.chain[0]
        solves_before = planner.stats["solves"]
        calls = []

        def hop(pid, k, payload):
            calls.append(pid)
            # fail the primary AND its same-segment hedge candidates on the
            # first hop attempt round, succeed for everyone else
            return payload, 10.0, pid != failed

        ex = HedgedChainExecutor(gcfg, hop, quantile_factor=1e9)
        report, _ = ex.execute(r.chain, t, plan=plan)
        assert report.success
        assert planner.stats["solves"] == solves_before

    def test_hedged_splice_excludes_failed_hedge_peer(self, gcfg):
        """When the hedge peer itself fails, the plan splice must not hand
        back that same peer (it would burn the one-shot repair)."""
        anchor = self._anchor(gcfg, replicas=4)
        t = anchor.snapshot(0.0)
        planner = RoutePlanner(6, k_best=8)
        r, plan = plan_route(t, 6, gcfg, tau=0.0, planner=planner)
        primary = r.chain[0]
        # the hedge peer find_replacement would pick: cheapest same-segment
        from repro.core.executor import find_replacement
        hidx = find_replacement(t, t.index_of(primary), 0.0)
        hedge_peer = int(t.peer_ids[hidx])
        dead = {primary, hedge_peer}

        def hop(pid, k, payload):
            return payload, 10.0, pid not in dead

        ex = HedgedChainExecutor(gcfg, hop, quantile_factor=1e9)
        report, _ = ex.execute(r.chain, t, tau=0.0, plan=plan)
        assert report.success
        assert ex.plan_repairs == 1
        assert not dead.intersection(report.chain)

    def test_resume_suffix_prefers_cheapest(self, gcfg):
        anchor = self._anchor(gcfg, replicas=4)
        t = anchor.snapshot(0.0)
        _, plan = plan_route(t, 6, gcfg, tau=0.0, k=8)
        failed = plan.chain_ids(0)[1]
        suffix = plan.resume_suffix(3, exclude={failed})
        assert suffix is not None and failed not in suffix
        w = effective_cost_vec(t.latency_ms, t.trust,
                               gcfg.request_timeout_ms)
        # cheapest same-segment survivor
        cands = [(float(w[i]), int(t.peer_ids[i])) for i in range(len(t))
                 if int(t.layer_start[i]) == 3
                 and int(t.peer_ids[i]) != failed]
        assert suffix[0] == min(cands)[1]

    def test_full_alternate_excludes(self, gcfg):
        anchor = self._anchor(gcfg)
        t = anchor.snapshot(0.0)
        _, plan = plan_route(t, 6, gcfg, tau=0.0, k=8)
        primary = plan.chain_ids(0)
        alt = plan.full_alternate(exclude=set(primary[:1]))
        if alt is not None:
            assert primary[0] not in alt


# ---------------------------------------------------------------------------
# CSR compile edge cases
# ---------------------------------------------------------------------------


class TestCompile:
    def test_out_of_range_segments_excluded(self, gcfg):
        a = AnchorRegistry(gcfg)
        a.register(0, 0, 3, now=0.0)
        a.register(1, 3, 6, now=0.0)
        a.register(2, 3, 9, now=0.0)          # overshoots L=6: useless
        a.register(3, 4, 4, now=0.0)          # degenerate: start == end
        for pid in range(4):
            a.heartbeat(pid, 0.0)
        t = a.snapshot(0.0)
        g = compile_table(t, 6)
        assert len(g.order) == 2              # only peers 0 and 1 remain
        r = gtrac_route(t, 6, gcfg, tau=0.0)
        assert r.feasible and r.chain == [0, 1]

    def test_empty_registry(self, gcfg):
        a = AnchorRegistry(gcfg)
        t = a.snapshot(0.0)
        r = gtrac_route(t, 6, gcfg, tau=0.0)
        assert not r.feasible

    def test_heap_reference_agreement_randomized(self, gcfg):
        """Planner.solve vs _dijkstra_layered on random weights/masks."""
        rng = np.random.default_rng(7)
        anchor = build_layered_anchor(gcfg, L=12, seed=2)
        t = snap(anchor)
        planner = RoutePlanner(12)
        for _ in range(10):
            w = rng.uniform(1, 500, size=len(t))
            mask = t.alive & (rng.random(len(t)) > 0.3)
            c1, d1 = planner.solve(t, w, mask)
            c2, d2 = _dijkstra_layered(t, mask, w, 12)
            if d2 == float("inf"):
                assert d1 == float("inf")
            else:
                assert d1 == pytest.approx(d2)
