"""Bounded One-Shot Repair semantics (Alg. 1, lines 9–15)."""

from repro.configs.base import GTRACConfig
from repro.core import AnchorRegistry, ChainExecutor, find_replacement
from repro.core.executor import split_reports


def make_table(gcfg, trusts, latencies, segments):
    a = AnchorRegistry(gcfg)
    for pid, (tr, lat, (s, e)) in enumerate(zip(trusts, latencies,
                                                segments)):
        a.register(pid, s, e, now=0.0, trust=tr, latency_ms=lat)
        a.heartbeat(pid, 0.0)
    return a.snapshot(0.0)


def scripted_hop_fn(outcomes):
    """outcomes: dict peer_id -> list of success bools (popped per call)."""
    calls = []

    def hop(pid, k, payload):
        calls.append(pid)
        ok = outcomes.get(pid, [True]).pop(0) if outcomes.get(pid) else True
        return payload, 50.0, ok

    hop.calls = calls
    return hop


class TestRepair:
    def test_replacement_same_segment_min_latency(self, gcfg):
        t = make_table(gcfg,
                       trusts=[1.0, 1.0, 1.0, 1.0],
                       latencies=[100, 300, 80, 90],
                       segments=[(0, 3), (0, 3), (0, 3), (3, 6)])
        r = find_replacement(t, 0, tau=gcfg.trust_floor)
        assert r == 2  # same segment, lowest latency, not the failed peer

    def test_replacement_never_below_floor(self, gcfg):
        t = make_table(gcfg, trusts=[1.0, 0.5], latencies=[100, 1],
                       segments=[(0, 3), (0, 3)])
        assert find_replacement(t, 0, tau=gcfg.trust_floor) is None

    def test_one_shot_swap_rescues_request(self, gcfg):
        t = make_table(gcfg, trusts=[1.0] * 3, latencies=[50, 60, 70],
                       segments=[(0, 3), (0, 3), (3, 6)])
        hop = scripted_hop_fn({0: [False]})       # peer 0 fails once
        ex = ChainExecutor(gcfg, hop)
        report, _ = ex.execute([0, 2], t)
        assert report.success and report.repaired
        assert report.repair_peer == 1            # swapped in
        assert hop.calls == [0, 1, 2]             # retried the SAME step
        # progress preserved: stage 1 (peer 2) ran exactly once

    def test_second_failure_aborts(self, gcfg):
        t = make_table(gcfg, trusts=[1.0] * 3, latencies=[50, 60, 70],
                       segments=[(0, 3), (0, 3), (3, 6)])
        hop = scripted_hop_fn({0: [False], 1: [False]})
        ex = ChainExecutor(gcfg, hop)
        report, _ = ex.execute([0, 2], t)
        assert not report.success
        assert report.failed_peer == 1            # the retry's failure
        assert hop.calls == [0, 1]                # exactly one retry, bounded

    def test_repair_disabled(self):
        gcfg = GTRACConfig(repair_enabled=False)
        t = make_table(gcfg, trusts=[1.0] * 2, latencies=[50, 60],
                       segments=[(0, 3), (0, 3)])
        hop = scripted_hop_fn({0: [False]})
        ex = ChainExecutor(gcfg, hop)
        report, _ = ex.execute([0], t)
        assert not report.success and hop.calls == [0]

    def test_attribution_after_rescue(self, gcfg):
        """The ORIGINAL failing hop is still penalised even when the repair
        rescues the request (preserves trust-learning integrity)."""
        t = make_table(gcfg, trusts=[1.0] * 3, latencies=[50, 60, 70],
                       segments=[(0, 3), (0, 3), (3, 6)])
        hop = scripted_hop_fn({0: [False]})
        ex = ChainExecutor(gcfg, hop)
        report, _ = ex.execute([0, 2], t)
        reports = split_reports(report)
        fails = [r for r in reports if not r.success]
        succ = [r for r in reports if r.success]
        assert len(fails) == 1 and fails[0].failed_peer == 0
        assert len(succ) == 1 and set(succ[0].chain) == {1, 2}

    def test_payload_flows_through_swapped_chain(self, gcfg):
        t = make_table(gcfg, trusts=[1.0] * 3, latencies=[50, 60, 70],
                       segments=[(0, 3), (0, 3), (3, 6)])

        def hop(pid, k, payload):
            if pid == 0:
                return payload, 10.0, False
            return payload + [pid], 10.0, True

        ex = ChainExecutor(gcfg, hop)
        report, payload = ex.execute([0, 2], t, payload=[])
        assert report.success and payload == [1, 2]
