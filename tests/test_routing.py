"""Routing algorithm tests: optimality, equivalence, and the paper's
risk-bound properties (hypothesis)."""
import numpy as np
import pytest

from repro.core import (
    brute_force_route,
    gtrac_route,
    k_max,
    larac_route,
    mr_route,
    naive_route,
    risk_bound,
    sp_route,
    trust_floor_for,
    verify_design_guarantee,
)
from repro.core.routing import enumerate_chains
from repro.core.routing_jax import route_batched

from _hyp import given, settings, st
from conftest import build_layered_anchor


def table_of(anchor):
    return anchor.snapshot(0.0)


class TestGtrac:
    def test_optimal_vs_bruteforce_on_pruned_graph(self, gcfg):
        """G-TRAC = exact shortest path over the trust-pruned DAG."""
        for seed in range(5):
            anchor = build_layered_anchor(gcfg, L=9, segments=(3,),
                                          replicas=5, seed=seed,
                                          trust_range=(0.8, 1.0))
            t = table_of(anchor)
            eps = 0.3
            kmax = k_max(9, 3)
            tau = trust_floor_for(eps, kmax)
            g = gtrac_route(t, 9, gcfg, tau=tau)
            bf = brute_force_route(t, 9, gcfg, epsilon=1 - tau ** kmax)
            if g.feasible:
                # brute force over the SAME feasible set can't beat it
                assert bf.total_cost <= g.total_cost + 1e-9
                assert g.reliability >= 1 - eps - 1e-9

    def test_respects_liveness(self, gcfg, layered_anchor):
        t = table_of(layered_anchor)
        t.alive[:] = False
        r = gtrac_route(t, 12, gcfg, tau=0.0)
        assert not r.feasible

    def test_prunes_low_trust(self, gcfg, layered_anchor):
        t = table_of(layered_anchor)
        r = gtrac_route(t, 12, gcfg, tau=0.999999)
        if r.feasible:
            assert all(t.trust[t.index_of(p)] >= 0.999999 for p in r.chain)

    def test_chain_is_contiguous(self, gcfg, layered_anchor):
        t = table_of(layered_anchor)
        r = gtrac_route(t, 12, gcfg, tau=0.0)
        assert r.feasible
        pos = 0
        for pid in r.chain:
            i = t.index_of(pid)
            assert t.layer_start[i] == pos
            pos = t.layer_end[i]
        assert pos == 12


class TestBaselines:
    def test_sp_minimises_latency(self, gcfg, layered_anchor):
        t = table_of(layered_anchor)
        r = sp_route(t, 12, gcfg)
        chains = enumerate_chains(t, t.alive, 12)
        best = min(float(np.sum(t.latency_ms[c])) for c in chains)
        assert r.total_cost == pytest.approx(best)

    def test_mr_maximises_reliability(self, gcfg, layered_anchor):
        t = table_of(layered_anchor)
        r = mr_route(t, 12, gcfg)
        chains = enumerate_chains(t, t.alive, 12)
        best = max(float(np.prod(t.trust[c])) for c in chains)
        assert r.reliability == pytest.approx(best)

    def test_naive_returns_complete_chain(self, gcfg, layered_anchor):
        t = table_of(layered_anchor)
        r = naive_route(t, 12, gcfg, rng=np.random.default_rng(0))
        assert r.feasible and r.hops >= 2

    def test_naive_default_rng_is_deterministic(self, gcfg, layered_anchor):
        # regression (repolint rng-discipline): the fallback RNG used to
        # be an unseeded default_rng(), so two identical calls could
        # sample different chains — run-to-run irreproducibility
        t = table_of(layered_anchor)
        a = naive_route(t, 12, gcfg)
        b = naive_route(t, 12, gcfg)
        assert a.chain == b.chain and a.total_cost == b.total_cost

    def test_larac_meets_constraint_when_feasible(self, gcfg):
        anchor = build_layered_anchor(gcfg, trust_range=(0.9, 1.0))
        t = table_of(anchor)
        eps = 0.5
        r = larac_route(t, 12, gcfg, epsilon=eps)
        if r.feasible:
            assert r.reliability >= 1 - eps - 1e-9


class TestBatchedRouter:
    def test_matches_dijkstra_cost(self, gcfg):
        for seed in range(4):
            anchor = build_layered_anchor(gcfg, L=12, seed=seed)
            t = table_of(anchor)
            taus = np.array([0.0, 0.6, 0.8, 0.95])
            ids, costs = route_batched(t, 12, gcfg, taus, k_max=6)
            for i, tau in enumerate(taus):
                ref = gtrac_route(t, 12, gcfg, tau=float(tau))
                if ref.feasible:
                    assert costs[i] == pytest.approx(ref.total_cost,
                                                     rel=1e-5)
                    chain = [p for p in ids[i] if p >= 0]
                    assert len(chain) == ref.hops
                else:
                    assert costs[i] >= 1e38

    def test_kernel_matches_jnp_dp(self, gcfg):
        import jax.numpy as jnp
        from repro.core.routing_jax import effective_costs, layered_dp
        from repro.kernels.ops import tropical_route
        anchor = build_layered_anchor(gcfg, L=12, replicas=8)
        t = table_of(anchor)
        taus = np.linspace(0, 0.9, 8)
        costs = effective_costs(jnp.asarray(t.latency_ms, jnp.float32),
                                jnp.asarray(t.trust, jnp.float32),
                                jnp.asarray(t.alive),
                                jnp.asarray(taus, jnp.float32),
                                gcfg.request_timeout_ms)
        starts = jnp.asarray(t.layer_start, jnp.int32)
        ends = jnp.asarray(t.layer_end, jnp.int32)
        d1, p1 = layered_dp(starts, ends, costs, total_layers=12)
        d2, p2 = tropical_route(starts, ends, costs, total_layers=12,
                                interpret=True, blk_r=8)
        np.testing.assert_allclose(np.where(np.asarray(d1) < 1e38, d1, 0),
                                   np.where(np.asarray(d2) < 1e38, d2, 0),
                                   rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


# ---------------------------------------------------------------------------
# Property-based: the paper's Lemma 1 + Design Guarantee
# ---------------------------------------------------------------------------


@given(tau=st.floats(0.5, 0.999), k=st.integers(1, 12),
       trusts=st.lists(st.floats(0.5, 1.0), min_size=1, max_size=12))
@settings(max_examples=200, deadline=None)
def test_lemma1_risk_bound(tau, k, trusts):
    """Risk(pi) <= 1 - tau^K for any chain of peers with r_p >= tau."""
    trusts = trusts[:k]
    if any(r < tau for r in trusts):
        return  # not drawn from the pruned graph
    rel = float(np.prod(trusts))
    assert 1 - rel <= risk_bound(tau, len(trusts)) + 1e-12


@given(eps=st.floats(0.01, 0.9), kmax=st.integers(1, 16),
       data=st.data())
@settings(max_examples=200, deadline=None)
def test_design_guarantee(eps, kmax, data):
    """tau = (1-eps)^(1/K_max) ==> any pruned-graph chain satisfies
    Rel >= 1 - eps (Appendix A)."""
    tau = trust_floor_for(eps, kmax)
    k = data.draw(st.integers(1, kmax))
    trusts = data.draw(st.lists(st.floats(tau, 1.0), min_size=k,
                                max_size=k))
    assert verify_design_guarantee(trusts, eps, kmax)


@given(eps=st.floats(0.01, 0.9), kmax=st.integers(1, 16))
@settings(max_examples=100, deadline=None)
def test_trust_floor_monotone(eps, kmax):
    tau = trust_floor_for(eps, kmax)
    assert 0 < tau < 1
    if kmax > 1:  # longer chains need a stricter floor
        assert tau > trust_floor_for(eps, kmax - 1) or kmax == 1
