"""Serving tests: engine correctness + G-TRAC routed pipeline produces the
same tokens as monolithic execution, and survives injected failures."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import GTRACConfig
from repro.models.api import build_model
from repro.serving.engine import ServingEngine
from repro.serving.gtrac_serve import GTRACPipelineServer, sample_token

KEY = jax.random.PRNGKey(7)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("gpt2-large").reduced(num_layers=4, vocab_size=128,
                                           remat=False)
    model = build_model(cfg)
    params = model.init(KEY)
    return cfg, model, params


def monolithic_greedy(cfg, model, params, prompt, n):
    """Reference: full-recompute greedy decode."""
    toks = jnp.asarray(prompt, jnp.int32)[None, :]
    out = []
    for _ in range(n):
        logits, _ = model.prefill(params, tokens=toks)
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks = jnp.concatenate([toks, jnp.full((1, 1), nxt, jnp.int32)], 1)
    return out


class TestEngine:
    def test_engine_matches_monolithic(self, tiny):
        cfg, model, params = tiny
        eng = ServingEngine(cfg, params)
        prompt = np.arange(1, 9)
        req = eng.submit(prompt, max_new_tokens=5)
        eng.run_batch([req])
        want = monolithic_greedy(cfg, model, params, prompt, 5)
        assert req.output == want

    def test_engine_batched_requests(self, tiny):
        cfg, model, params = tiny
        eng = ServingEngine(cfg, params)
        reqs = [eng.submit(np.arange(1, 9) + i, max_new_tokens=4)
                for i in range(3)]
        eng.run_batch(reqs)
        assert all(len(r.output) == 4 for r in reqs)


class TestGTRACServer:
    def test_routed_pipeline_matches_monolithic(self, tiny):
        """With only golden peers (no failures), the chain of real stage
        computations must reproduce monolithic greedy decoding exactly."""
        cfg, model, params = tiny
        srv = GTRACPipelineServer(cfg, params, layers_per_stage=2,
                                  replicas={"golden": 2}, algorithm="gtrac",
                                  seed=0)
        prompt = np.arange(1, 9)
        out, met = srv.generate(prompt, max_new_tokens=5)
        want = monolithic_greedy(cfg, model, params, prompt, 5)
        assert list(out) == want
        assert met.failures == 0 and met.tokens == 5

    def test_survives_injected_failures(self, tiny):
        """Honeypot-heavy peer pool: trust learning + repair keep serving."""
        cfg, model, params = tiny
        srv = GTRACPipelineServer(cfg, params, layers_per_stage=2,
                                  replicas={"honeypot": 2, "golden": 2},
                                  algorithm="gtrac", seed=1)
        done = 0
        for rid in range(6):
            out, met = srv.generate(np.arange(1, 9), max_new_tokens=4,
                                    request_id=rid)
            done += met.tokens == 4
        assert done >= 4  # converges to golden peers after early strikes

    def test_sp_baseline_worse_than_gtrac(self, tiny):
        cfg, model, params = tiny

        def run(algo, seed):
            srv = GTRACPipelineServer(
                cfg, params, layers_per_stage=2,
                replicas={"honeypot": 3, "golden": 1, "turtle": 1},
                algorithm=algo, seed=seed)
            ok = 0
            for rid in range(8):
                _, met = srv.generate(np.arange(1, 9), max_new_tokens=3,
                                      request_id=rid)
                ok += met.tokens == 3
            return ok / 8

        g = np.mean([run("gtrac", s) for s in range(2)])
        s = np.mean([run("sp", s) for s in range(2)])
        assert g >= s  # the honey-pot effect (paper §VI-A)

    def test_nongreedy_sampling_can_emit_non_argmax(self, tiny):
        """Regression: generate(greedy=False) was dead code — both
        branches of the conditional took argmax. Real temperature
        sampling off the testbed RNG must be able to leave the argmax
        chain (same params + prompt, so any divergence is sampling)."""
        cfg, model, params = tiny

        def build():
            return GTRACPipelineServer(cfg, params, layers_per_stage=2,
                                       replicas={"golden": 2},
                                       algorithm="gtrac", seed=0)

        prompt = np.arange(1, 9)
        greedy_out, gm = build().generate(prompt, max_new_tokens=6,
                                          greedy=True)
        sampled, sm = build().generate(prompt, max_new_tokens=6,
                                       greedy=False, temperature=8.0)
        assert gm.tokens == 6 and sm.tokens == 6
        assert all(0 <= t < cfg.vocab_size for t in sampled)
        assert list(sampled) != list(greedy_out)   # pre-fix: identical

    def test_sample_token_temperature_law(self):
        """Low temperature concentrates on the argmax; high temperature
        spreads — and every draw comes off the supplied RNG."""
        logits = np.zeros(32)
        logits[7] = 4.0
        cold = {sample_token(logits, np.random.default_rng(0), 0.05)
                for _ in range(50)}
        assert cold == {7}
        rng = np.random.default_rng(0)
        hot = [sample_token(logits, rng, 4.0) for _ in range(300)]
        assert 7 in hot
        assert any(t != 7 for t in hot)
        # determinism per seed: the testbed RNG is the only entropy
        rng2 = np.random.default_rng(0)
        assert hot == [sample_token(logits, rng2, 4.0)
                       for _ in range(300)]

    def test_windowed_serving_with_relay_plane(self, tiny):
        """run_queue serves correctly off a relay-enabled gossip seeker
        and surfaces relay totals in ServeMetrics."""
        cfg, model, params = tiny
        gcfg = GTRACConfig(gossip_enabled=True, relay_enabled=True,
                           gossip_seekers=4, anchor_shards=4,
                           gossip_fanout=2, relay_fanout=2)
        srv = GTRACPipelineServer(cfg, params, layers_per_stage=2,
                                  replicas={"golden": 2}, gcfg=gcfg,
                                  seed=0)
        for _ in range(2):
            srv.submit(np.arange(1, 9), max_new_tokens=3)
        done = srv.run_queue()
        assert all(len(r.output) == 3 for r in done)
        assert srv.gossip.relay is not None
        assert srv.gossip.relay.stats.rounds >= 1
        assert done[0].metrics.relay_msgs > 0
        assert done[0].metrics.relay_bytes > 0

    def test_repair_preserves_correct_output(self, tiny):
        """A repaired (swapped) chain must still compute the right tokens —
        stateless hops make repair semantically transparent."""
        cfg, model, params = tiny
        srv = GTRACPipelineServer(cfg, params, layers_per_stage=2,
                                  replicas={"honeypot": 2, "golden": 2},
                                  algorithm="gtrac", seed=5)
        want = monolithic_greedy(cfg, model, params, np.arange(1, 9), 4)
        for rid in range(8):
            out, met = srv.generate(np.arange(1, 9), max_new_tokens=4,
                                    request_id=rid)
            if met.tokens == 4:
                assert list(out) == want
