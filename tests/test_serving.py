"""Serving tests: engine correctness + G-TRAC routed pipeline produces the
same tokens as monolithic execution, and survives injected failures."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import GTRACConfig
from repro.models.api import build_model
from repro.serving.api import SubmitSpec
from repro.serving.engine import ServingEngine
from repro.serving.gtrac_serve import GTRACPipelineServer, sample_token

KEY = jax.random.PRNGKey(7)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("gpt2-large").reduced(num_layers=4, vocab_size=128,
                                           remat=False)
    model = build_model(cfg)
    params = model.init(KEY)
    return cfg, model, params


def monolithic_greedy(cfg, model, params, prompt, n):
    """Reference: full-recompute greedy decode."""
    toks = jnp.asarray(prompt, jnp.int32)[None, :]
    out = []
    for _ in range(n):
        logits, _ = model.prefill(params, tokens=toks)
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks = jnp.concatenate([toks, jnp.full((1, 1), nxt, jnp.int32)], 1)
    return out


class TestEngine:
    def test_engine_matches_monolithic(self, tiny):
        cfg, model, params = tiny
        eng = ServingEngine(cfg, params)
        prompt = np.arange(1, 9)
        req = eng.submit(SubmitSpec(prompt=prompt, max_new_tokens=5))
        eng.run_batch([req])
        want = monolithic_greedy(cfg, model, params, prompt, 5)
        assert req.output == want

    def test_engine_batched_requests(self, tiny):
        cfg, model, params = tiny
        eng = ServingEngine(cfg, params)
        reqs = [eng.submit(SubmitSpec(prompt=np.arange(1, 9) + i,
                              max_new_tokens=4))
                for i in range(3)]
        eng.run_batch(reqs)
        assert all(len(r.output) == 4 for r in reqs)


class TestGTRACServer:
    def test_routed_pipeline_matches_monolithic(self, tiny):
        """With only golden peers (no failures), the chain of real stage
        computations must reproduce monolithic greedy decoding exactly."""
        cfg, model, params = tiny
        srv = GTRACPipelineServer(cfg, params, layers_per_stage=2,
                                  replicas={"golden": 2}, algorithm="gtrac",
                                  seed=0)
        prompt = np.arange(1, 9)
        out, met = srv.generate(prompt, max_new_tokens=5)
        want = monolithic_greedy(cfg, model, params, prompt, 5)
        assert list(out) == want
        assert met.failures == 0 and met.tokens == 5

    def test_survives_injected_failures(self, tiny):
        """Honeypot-heavy peer pool: trust learning + repair keep serving."""
        cfg, model, params = tiny
        srv = GTRACPipelineServer(cfg, params, layers_per_stage=2,
                                  replicas={"honeypot": 2, "golden": 2},
                                  algorithm="gtrac", seed=1)
        done = 0
        for rid in range(6):
            out, met = srv.generate(np.arange(1, 9), max_new_tokens=4,
                                    request_id=rid)
            done += met.tokens == 4
        assert done >= 4  # converges to golden peers after early strikes

    def test_sp_baseline_worse_than_gtrac(self, tiny):
        cfg, model, params = tiny

        def run(algo, seed):
            srv = GTRACPipelineServer(
                cfg, params, layers_per_stage=2,
                replicas={"honeypot": 3, "golden": 1, "turtle": 1},
                algorithm=algo, seed=seed)
            ok = 0
            for rid in range(8):
                _, met = srv.generate(np.arange(1, 9), max_new_tokens=3,
                                      request_id=rid)
                ok += met.tokens == 3
            return ok / 8

        g = np.mean([run("gtrac", s) for s in range(2)])
        s = np.mean([run("sp", s) for s in range(2)])
        assert g >= s  # the honey-pot effect (paper §VI-A)

    def test_nongreedy_sampling_can_emit_non_argmax(self, tiny):
        """Regression: generate(greedy=False) was dead code — both
        branches of the conditional took argmax. Real temperature
        sampling off the testbed RNG must be able to leave the argmax
        chain (same params + prompt, so any divergence is sampling)."""
        cfg, model, params = tiny

        def build():
            return GTRACPipelineServer(cfg, params, layers_per_stage=2,
                                       replicas={"golden": 2},
                                       algorithm="gtrac", seed=0)

        prompt = np.arange(1, 9)
        greedy_out, gm = build().generate(prompt, max_new_tokens=6,
                                          greedy=True)
        sampled, sm = build().generate(prompt, max_new_tokens=6,
                                       greedy=False, temperature=8.0)
        assert gm.tokens == 6 and sm.tokens == 6
        assert all(0 <= t < cfg.vocab_size for t in sampled)
        assert list(sampled) != list(greedy_out)   # pre-fix: identical

    def test_sample_token_temperature_law(self):
        """Low temperature concentrates on the argmax; high temperature
        spreads — and every draw comes off the supplied RNG."""
        logits = np.zeros(32)
        logits[7] = 4.0
        cold = {sample_token(logits, np.random.default_rng(0), 0.05)
                for _ in range(50)}
        assert cold == {7}
        rng = np.random.default_rng(0)
        hot = [sample_token(logits, rng, 4.0) for _ in range(300)]
        assert 7 in hot
        assert any(t != 7 for t in hot)
        # determinism per seed: the testbed RNG is the only entropy
        rng2 = np.random.default_rng(0)
        assert hot == [sample_token(logits, rng2, 4.0)
                       for _ in range(300)]

    def test_windowed_serving_with_relay_plane(self, tiny):
        """run_queue serves correctly off a relay-enabled gossip seeker
        and surfaces relay totals in ServeMetrics."""
        cfg, model, params = tiny
        gcfg = GTRACConfig(gossip_enabled=True, relay_enabled=True,
                           gossip_seekers=4, anchor_shards=4,
                           gossip_fanout=2, relay_fanout=2)
        srv = GTRACPipelineServer(cfg, params, layers_per_stage=2,
                                  replicas={"golden": 2}, gcfg=gcfg,
                                  seed=0)
        for _ in range(2):
            srv.submit(SubmitSpec(prompt=np.arange(1, 9), max_new_tokens=3))
        done = srv.run_queue()
        assert all(len(r.output) == 3 for r in done)
        assert srv.gossip.relay is not None
        assert srv.gossip.relay.stats.rounds >= 1
        assert done[0].metrics.relay_msgs > 0
        assert done[0].metrics.relay_bytes > 0

    def test_repair_preserves_correct_output(self, tiny):
        """A repaired (swapped) chain must still compute the right tokens —
        stateless hops make repair semantically transparent."""
        cfg, model, params = tiny
        srv = GTRACPipelineServer(cfg, params, layers_per_stage=2,
                                  replicas={"honeypot": 2, "golden": 2},
                                  algorithm="gtrac", seed=5)
        want = monolithic_greedy(cfg, model, params, np.arange(1, 9), 4)
        for rid in range(8):
            out, met = srv.generate(np.arange(1, 9), max_new_tokens=4,
                                    request_id=rid)
            if met.tokens == 4:
                assert list(out) == want


class TestSubmitSpecAPI:
    def test_spec_validation(self):
        with pytest.raises(ValueError):
            SubmitSpec(prompt=np.arange(4), kind="bogus")
        with pytest.raises(ValueError):
            SubmitSpec(prompt=np.arange(4), max_new_tokens=0)
        spec = SubmitSpec(prompt=[1, 2, 3])
        assert spec.prompt.dtype == np.int32 and spec.kind == "auto"

    def test_engine_shim_warns_and_behaves(self, tiny):
        cfg, model, params = tiny
        eng = ServingEngine(cfg, params)
        with pytest.deprecated_call():
            req = eng.submit(np.arange(1, 5), max_new_tokens=2)
        assert req.max_new_tokens == 2 and req.request_id == 0

    def test_server_shim_warns(self, tiny):
        cfg, model, params = tiny
        srv = GTRACPipelineServer(cfg, params, layers_per_stage=2,
                                  replicas={"golden": 2}, seed=0)
        with pytest.deprecated_call():
            req = srv.submit(np.arange(1, 5), max_new_tokens=2)
        assert req.request_id == 10_000

    def test_pinned_request_id_advances_counter(self, tiny):
        cfg, model, params = tiny
        eng = ServingEngine(cfg, params)
        a = eng.submit(SubmitSpec(prompt=np.arange(4)))
        b = eng.submit(SubmitSpec(prompt=np.arange(4), request_id=7))
        c = eng.submit(SubmitSpec(prompt=np.arange(4)))
        assert (a.request_id, b.request_id, c.request_id) == (0, 7, 8)


class TestDisaggregatedServing:
    def test_long_prompt_chunked_prefill_matches_monolithic(self, tiny):
        """A stream prefilled in dedicated chunks must emit exactly the
        tokens monolithic greedy decoding would — chunking and warm
        promotion change scheduling, never semantics."""
        cfg, model, params = tiny
        gcfg = GTRACConfig(disaggregate=True, prefill_chunk_tokens=8,
                           kv_reuse_bonus=0.25)
        srv = GTRACPipelineServer(cfg, params, layers_per_stage=2,
                                  replicas={"golden": 2}, gcfg=gcfg, seed=0)
        long_p, short_p = np.arange(1, 25), np.arange(1, 7)
        r1 = srv.submit(SubmitSpec(prompt=long_p, max_new_tokens=4))
        r2 = srv.submit(SubmitSpec(prompt=short_p, max_new_tokens=4))
        done = srv.run_queue()
        assert len(done) == 2
        assert r1.output == monolithic_greedy(cfg, model, params, long_p, 4)
        assert r2.output == monolithic_greedy(cfg, model, params, short_p, 4)
        assert r1.metrics.prefill_chunks == 3        # 24 tokens / 8
        assert r1.metrics.prefill_tokens == 24
        assert r2.metrics.prefill_chunks == 0        # inline prefill
        # emission accounting: TTFT set, stamps nondecreasing, and the
        # short stream reaches its first token before the chunked one
        for r in (r1, r2):
            assert r.metrics.ttft_ms > 0 and len(r.metrics.emit_ms) == 4
            assert all(b >= a for a, b in zip(r.metrics.emit_ms,
                                              r.metrics.emit_ms[1:]))
        assert r2.metrics.ttft_ms < r1.metrics.ttft_ms
        # warm handoff: the promoted stream decodes on its warm chain
        assert r1.metrics.kv_warm_hits >= 1

    def test_multi_token_charges_never_poison_latency_ema(self, tiny):
        """The anchor's latency_est_ms means ONE decode step. Prefill
        chunks and cold recomputes are charged multi-token wall latency,
        but the report fed to the EMA must be rescaled to its
        single-token equivalent — unnormalized, a 8-token chunk makes
        its peers look ~8x slow, routing flees to the cold replica, and
        chains ping-pong (each flip a full-prefix recompute)."""
        cfg, model, params = tiny
        gcfg = GTRACConfig(disaggregate=True, prefill_chunk_tokens=8,
                           kv_reuse_bonus=0.25)
        srv = GTRACPipelineServer(cfg, params, layers_per_stage=2,
                                  replicas={"golden": 2}, gcfg=gcfg, seed=0)
        srv.submit(SubmitSpec(prompt=np.arange(1, 25), max_new_tokens=4))
        srv.run_queue()
        table = srv.bed.anchor.snapshot(srv.bed.now)
        for pid, est in zip(table.peer_ids, table.latency_ms):
            peer = srv.bed.peers[int(pid)]
            one_tok = peer.compute_ms(1) + peer.net_delay_ms
            # EMA stays in single-token units (jitter sigma is 0.1; an
            # unnormalized 8-token chunk would land near 8x one_tok)
            assert est < 2.0 * one_tok
        assert not srv._tok_scale               # every charge consumed

    def test_explicit_kind_overrides_bucket(self, tiny):
        cfg, model, params = tiny
        gcfg = GTRACConfig(disaggregate=True, prefill_chunk_tokens=8)
        srv = GTRACPipelineServer(cfg, params, layers_per_stage=2,
                                  replicas={"golden": 2}, gcfg=gcfg, seed=0)
        pinned_pre = srv.submit(SubmitSpec(prompt=np.arange(1, 7),
                                           max_new_tokens=2, kind="prefill"))
        pinned_dec = srv.submit(SubmitSpec(prompt=np.arange(1, 25),
                                           max_new_tokens=2, kind="decode"))
        srv.run_queue()
        assert pinned_pre.metrics.prefill_chunks >= 1
        assert pinned_dec.metrics.prefill_chunks == 0
        assert len(pinned_pre.output) == 2 and len(pinned_dec.output) == 2
