"""Sharded anchor registries: composed-snapshot parity, version-vector
staleness, per-shard replication / shard loss, and churn (PR 3)."""
import numpy as np
import pytest

from repro.configs.base import GTRACConfig
from repro.core.failover import ReplicatedAnchor
from repro.core.planner import RoutePlanner, plan_route
from repro.core.registry import AnchorRegistry
from repro.core.sharding import ShardedAnchorRegistry, make_registry, stable_peer_hash
from repro.core.types import ExecReport, HopReport

L = 12


def populate(reg, n=48, seed=1, now=0.0):
    rng = np.random.default_rng(seed)
    for pid in range(n):
        s = (pid % 4) * 3
        reg.register(pid, s, s + 3, now=now,
                     trust=float(rng.uniform(0.5, 1.0)),
                     latency_ms=float(rng.uniform(10, 300)))
        reg.heartbeat(pid, now)


def both(cfg, n_shards, n=48, seed=1):
    mono = AnchorRegistry(cfg)
    sharded = ShardedAnchorRegistry(cfg, n_shards=n_shards)
    populate(mono, n=n, seed=seed)
    populate(sharded, n=n, seed=seed)
    return mono, sharded


def assert_tables_equal(tm, ts):
    assert np.array_equal(tm.peer_ids, ts.peer_ids)
    assert np.array_equal(tm.layer_start, ts.layer_start)
    assert np.array_equal(tm.layer_end, ts.layer_end)
    assert np.array_equal(tm.trust, ts.trust)        # bit-equal, not approx
    assert np.array_equal(tm.latency_ms, ts.latency_ms)
    assert np.array_equal(tm.alive, ts.alive)


def assert_plans_equal(cfg, tm, ts, tau=0.8):
    pm, ps = RoutePlanner(L, k_best=4), RoutePlanner(L, k_best=4)
    _, plan_m = plan_route(tm, L, cfg, tau=tau, planner=pm)
    _, plan_s = plan_route(ts, L, cfg, tau=tau, planner=ps)
    assert plan_m.chain_rows == plan_s.chain_rows
    assert plan_m.costs == plan_s.costs
    return plan_m


class TestComposedParity:
    @pytest.mark.parametrize("n_shards", [1, 3, 4, 16])
    def test_bit_identical_plans(self, gcfg, n_shards):
        """S=1 and S>1 composed snapshots route bit-identically to the
        monolithic registry over the same peers."""
        mono, sharded = both(gcfg, n_shards)
        tm, ts = mono.snapshot(0.0), sharded.snapshot(0.0)
        assert_tables_equal(tm, ts)
        plan = assert_plans_equal(gcfg, tm, ts)
        assert plan.feasible

    def test_parity_survives_reports_and_heartbeats(self, gcfg):
        mono, sharded = both(gcfg, 4)
        rep_fail = ExecReport(False, [3], [HopReport(3, 120.0, False)],
                              failed_peer=3)
        rep_ok = ExecReport(True, [0, 5, 9],
                            [HopReport(p, 40.0, True) for p in (0, 5, 9)])
        for reg in (mono, sharded):
            reg.apply_report(rep_fail)
            reg.apply_report(rep_ok)
            reg.heartbeat_all(range(0, 48, 2), 5.0)
        tm, ts = mono.snapshot(6.0), sharded.snapshot(6.0)
        assert_tables_equal(tm, ts)
        assert_plans_equal(gcfg, tm, ts)

    def test_parity_after_deregister_and_reregister(self, gcfg):
        """Monolithic dict semantics: re-registering an existing peer keeps
        its position; deregister + register moves it to the end."""
        mono, sharded = both(gcfg, 4)
        for reg in (mono, sharded):
            reg.register(7, 3, 6, now=1.0, trust=0.9, latency_ms=50.0)
            reg.heartbeat(7, 1.0)          # re-register in place
            reg.deregister(11)
            reg.register(11, 6, 9, now=1.0, trust=0.7, latency_ms=80.0)
            reg.heartbeat(11, 1.0)         # back at the end
        tm, ts = mono.snapshot(2.0), sharded.snapshot(2.0)
        assert_tables_equal(tm, ts)
        assert_plans_equal(gcfg, tm, ts)

    def test_cross_shard_tau_floor_pruning_parity(self):
        """Sweep (TTL expiry + decay toward init_trust) prunes the same
        peers on both sides, and tau-floor masks then match row for row."""
        cfg = GTRACConfig(ttl_expire_factor=2.0, trust_decay_rate=0.02,
                          init_trust=0.9)
        mono, sharded = both(cfg, 4)
        for reg in (mono, sharded):        # odd pids go silent -> expire
            reg.heartbeat_all(range(0, 48, 2), 40.0)
        e_m = mono.sweep(50.0)
        e_s = sharded.sweep(50.0)
        assert e_m == e_s == 24
        tm, ts = mono.snapshot(50.0), sharded.snapshot(50.0)
        assert_tables_equal(tm, ts)
        for tau in (0.6, 0.8, 0.95):
            mask_m = tm.alive & (tm.trust >= tau)
            mask_s = ts.alive & (ts.trust >= tau)
            assert np.array_equal(mask_m, mask_s)
            assert_plans_equal(cfg, tm, ts, tau=tau)

    def test_layer_affinity_placement(self, gcfg):
        """shard_by='layer': all replicas of one stage slot share a shard;
        plans still bit-identical."""
        mono = AnchorRegistry(gcfg)
        sharded = ShardedAnchorRegistry(gcfg, n_shards=4, shard_by="layer")
        populate(mono)
        populate(sharded)
        for pid in range(48):
            expect = stable_peer_hash((pid % 4) * 3) % 4
            assert sharded.owner_of(pid) == expect
        assert_tables_equal(mono.snapshot(0.0), sharded.snapshot(0.0))
        assert_plans_equal(gcfg, mono.snapshot(0.0), sharded.snapshot(0.0))

    def test_make_registry_factory(self, gcfg):
        assert isinstance(make_registry(gcfg, 1), AnchorRegistry)
        reg = make_registry(gcfg, 8)
        assert isinstance(reg, ShardedAnchorRegistry)
        assert reg.n_shards == 8


class TestVersionVector:
    def test_nochange_fast_path_is_zero_copy(self, gcfg):
        _, sharded = both(gcfg, 4)
        t0 = sharded.snapshot(0.0)
        assert sharded.snapshot(1.0) is t0            # identical object
        sharded.heartbeat(0, 1.0)                     # no liveness flip
        assert sharded.snapshot(1.0) is t0
        assert sharded.version_vector == tuple(
            sh.version for sh in sharded.shards)

    def test_only_dirty_shard_rebuilds(self, gcfg):
        _, sharded = both(gcfg, 4)
        sharded.snapshot(0.0)
        shard_tables = [sh.snapshot(0.0) for sh in sharded.shards]
        victim = 5
        owner = sharded.owner_of(victim)
        sharded.apply_report(ExecReport(
            True, [victim], [HopReport(victim, 33.0, True)]))
        t1 = sharded.snapshot(0.0)
        for i, sh in enumerate(sharded.shards):
            same = sh.snapshot(0.0) is shard_tables[i]
            assert same == (i != owner)
        assert float(t1.trust[t1.index_of(victim)]) > 0.0

    def test_version_monotonic_and_distinct_per_rebuild(self, gcfg):
        _, sharded = both(gcfg, 4)
        seen = []
        t = sharded.snapshot(0.0)
        seen.append(t.version)
        sharded.set_trust(3, 0.42)
        t = sharded.snapshot(0.0)
        seen.append(t.version)
        sharded.register(99, 0, 3, now=0.0)
        sharded.heartbeat(99, 0.0)
        topo_before = sharded.topo_version
        t = sharded.snapshot(0.0)
        seen.append(t.version)
        assert sharded.topo_version == topo_before + 1
        assert seen == sorted(seen) and len(set(seen)) == len(seen)

    def test_liveness_flip_without_shard_mutation(self, gcfg):
        """Staleness detection: no shard version moved (heartbeats mutate
        mirrors in place), yet the composed snapshot must see TTL expiry
        through its write-through heartbeat column."""
        _, sharded = both(gcfg, 4)
        t0 = sharded.snapshot(0.0)
        assert t0.alive.all()
        vec = sharded.version_vector
        live = list(range(0, 48, 3))
        sharded.heartbeat_all(live, 20.0)
        t1 = sharded.snapshot(21.0)
        assert sharded.version_vector == vec     # shards never bumped
        assert t1 is not t0 and t1.version > t0.version
        expect = np.zeros(48, bool)
        expect[live] = True
        assert np.array_equal(t1.alive, expect)
        # columns other than alive are shared zero-copy with t0
        assert t1.trust is t0.trust and t1.peer_ids is t0.peer_ids

    def test_stale_seeker_keyed_by_version(self, gcfg):
        """A consumer holding an old composed table can detect staleness
        purely from the version counter."""
        _, sharded = both(gcfg, 4)
        old = sharded.snapshot(0.0)
        sharded.set_trust(1, 0.11)
        new = sharded.snapshot(0.0)
        assert new.version > old.version
        assert old.version != new.version  # distinct tables, distinct keys


class TestShardReplication:
    def test_backup_promotes_with_composed_parity(self, gcfg):
        ra = ReplicatedAnchor(gcfg, n_backups=1, shards=4)
        populate(ra)
        ra.tick(gcfg.gossip_period_s + 0.1)
        before = ra.snapshot(0.5)
        ra.crash_primary()
        assert ra.maybe_failover(now=100.0)
        # registration order (the seq column) survived replication: the
        # promoted backup's composed snapshot is row-identical
        after = ra.snapshot(100.0)
        assert np.array_equal(before.peer_ids, after.peer_ids)
        assert np.array_equal(before.trust, after.trust)

    def test_shard_loss_and_single_shard_restore(self, gcfg):
        ra = ReplicatedAnchor(gcfg, n_backups=2, shards=4)
        populate(ra)
        ra.tick(gcfg.gossip_period_s + 0.1)
        # post-replication update on a shard that will SURVIVE the loss
        survivor = next(pid for pid in range(48)
                        if ra.primary.owner_of(pid) != 2)
        ra.primary.set_trust(survivor, 0.123)
        before = ra.snapshot(1.0)
        lost = ra.primary.lose_shard(2)
        assert lost > 0
        assert len(ra.snapshot(1.1)) == 48 - lost
        assert ra.restore_shard(2)
        after = ra.snapshot(1.2)
        # full parity incl. registration order...
        assert np.array_equal(before.peer_ids, after.peer_ids)
        assert np.array_equal(before.trust, after.trust)
        # ...and the survivor shard's newer-than-replication write intact
        assert float(after.trust[after.index_of(survivor)]) == 0.123

    def test_dirty_shard_delta_replication(self, gcfg):
        ra = ReplicatedAnchor(gcfg, n_backups=1, shards=4)
        populate(ra)
        ra.tick(gcfg.gossip_period_s + 0.1)
        vec = list(ra._shipped[1])
        assert tuple(vec) == ra.primary.version_vector
        # a quiet tick re-ships no state (delivery ledger unchanged)
        ra.tick(2 * gcfg.gossip_period_s + 0.2)
        assert ra._shipped[1] == vec
        ra.primary.set_trust(0, 0.5)
        ra.tick(3 * gcfg.gossip_period_s + 0.3)
        assert ra._shipped[1] != vec
        assert ra.replicas[1].peers[0].trust == 0.5

    def test_tick_between_loss_and_restore_preserves_backup_copy(self, gcfg):
        """A gossip tick firing after lose_shard must not replicate the
        emptied shard over the backups' last good copy — restore_shard
        would otherwise 'restore' nothing and report success."""
        ra = ReplicatedAnchor(gcfg, n_backups=1, shards=4)
        populate(ra)
        ra.tick(gcfg.gossip_period_s + 0.1)
        before = ra.snapshot(1.0)
        lost = ra.primary.lose_shard(2)
        ra.tick(2 * gcfg.gossip_period_s + 0.2)   # the racing tick
        assert ra.restore_shard(2)
        after = ra.snapshot(2.0)
        assert len(after) == len(before) == 48
        assert np.array_equal(before.peer_ids, after.peer_ids)
        assert lost > 0 and not ra.primary.lost_shards

    def test_restore_never_adopts_from_a_copyless_backup(self, gcfg):
        """restore_shard must consult the ship ledger: a backup that was
        dead during the only full ship (then revived without a tick) holds
        no copy, and adopting its empty shard would silently lose peers
        while another live backup still has the real state."""
        ra = ReplicatedAnchor(gcfg, n_backups=2, shards=4)
        populate(ra)
        # before any tick, nobody holds a copy at all
        ra.primary.lose_shard(0)
        assert not ra.restore_shard(0)
        # re-seed shard 0 and ship while backup 1 is dead
        for pid in range(48):
            if ra.primary.owner_of(pid) is None:
                seg = (pid % 4) * 3
                ra.register(pid, seg, seg + 3, now=0.0)
                ra.heartbeat(pid, 0.0)
        ra.alive[1] = False
        ra.tick(gcfg.gossip_period_s + 0.1)        # only backup 2 gets state
        ra.alive[1] = True                         # revives, no tick yet
        n_before = len(ra.snapshot(1.0))
        lost = ra.primary.lose_shard(2)
        assert ra.restore_shard(2)                 # must pick backup 2
        assert len(ra.snapshot(1.1)) == n_before
        assert lost > 0

    def test_revived_backup_gets_full_reship(self, gcfg):
        """A backup that was dead during a dirty-shard ship must receive
        the full state when it revives — heartbeat-only deltas against
        state it never saw would leave it stale forever."""
        ra = ReplicatedAnchor(gcfg, n_backups=2, shards=4)
        populate(ra)
        ra.tick(gcfg.gossip_period_s + 0.1)
        ra.alive[2] = False                        # backup 2 goes down
        ra.primary.set_trust(0, 0.123)
        ra.tick(2 * gcfg.gossip_period_s + 0.2)    # ships past backup 2
        assert ra.replicas[1].peers[0].trust == 0.123
        ra.alive[2] = True                         # revival
        ra.tick(3 * gcfg.gossip_period_s + 0.3)
        assert ra.replicas[2].peers[0].trust == 0.123

    def test_clean_shards_ship_heartbeats(self, gcfg):
        """Heartbeats never bump shard versions, so the dirty-delta tick
        must still ship liveness columns — otherwise a backup promoted
        after a quiet stretch TTL-expires every live peer."""
        ra = ReplicatedAnchor(gcfg, n_backups=1, shards=4)
        populate(ra)
        ra.tick(gcfg.gossip_period_s + 0.1)       # full ship at t~2
        vec = ra._shipped
        # a long quiet stretch: only heartbeat traffic, well past TTL
        t = 100.0
        for pid in range(48):
            ra.heartbeat(pid, t)
        ra.tick(t)                                # clean shards: hb-only ship
        assert ra._shipped == vec                 # no state re-ship happened
        ra.crash_primary()
        assert ra.maybe_failover(now=t + 1.0)
        promoted = ra.snapshot(t + 1.0)
        assert promoted.alive.all()               # liveness survived
        assert ra.primary.sweep(t + 1.0, expire_after_s=30.0) == 0

    def test_cross_shard_move_keeps_registration_order(self, gcfg):
        """shard_by='layer': re-registering a peer onto a different layer
        slot moves it across shards but, like the monolithic dict, keeps
        its registration position in the composed row order."""
        mono = AnchorRegistry(gcfg)
        sharded = ShardedAnchorRegistry(gcfg, n_shards=4, shard_by="layer")
        populate(mono, n=24)
        populate(sharded, n=24)
        mover = 5
        old = sharded.owner_of(mover)
        for reg in (mono, sharded):               # 0->3 moves the shard
            reg.register(mover, 6, 9, now=1.0, trust=0.8, latency_ms=40.0)
            reg.heartbeat(mover, 1.0)
        assert sharded.owner_of(mover) != old
        tm, ts = mono.snapshot(2.0), sharded.snapshot(2.0)
        assert_tables_equal(tm, ts)
        assert_plans_equal(gcfg, tm, ts)

    def test_monolithic_group_unchanged(self, gcfg):
        """shards=1 keeps the original whole-state replication path."""
        ra = ReplicatedAnchor(gcfg, n_backups=1)
        assert isinstance(ra.primary, AnchorRegistry)
        populate(ra, n=6)
        ra.tick(gcfg.gossip_period_s + 0.1)
        assert len(ra.replicas[1].peers) == 6
        with pytest.raises(ValueError):
            ra.restore_shard(0)


class TestChurn:
    def test_shard_aware_churn_keeps_routing_feasible(self):
        from repro.core.planner import plan_route as pr
        from repro.sim.testbed import build_scaling_testbed, run_churn
        cfg = GTRACConfig()
        bed = build_scaling_testbed(96, cfg=cfg, seed=0, shards=4)
        stats = run_churn(bed, windows=8, window_s=10.0,
                          joins_per_window=3, crashes_per_window=2,
                          expire_after_s=25.0)
        assert stats.joined == 24 and stats.crashed == 16
        assert stats.expired > 0            # TTL sweeps really fired
        assert stats.snapshots_rebuilt > 0
        t = bed.anchor.snapshot(bed.now)
        r, _ = pr(t, bed.total_layers, cfg, tau=0.0)
        assert r.feasible

    def test_crash_anchor_shard(self, gcfg):
        from repro.sim.testbed import build_scaling_testbed
        bed = build_scaling_testbed(64, cfg=gcfg, seed=0, shards=4)
        pids = bed.crash_anchor_shard(1)
        assert pids and all(bed.anchor.owner_of(p) == 1 for p in pids)
        assert all(not bed.peers[p].alive for p in pids)


# ---------------------------------------------------------------------------
# Version-bump contract (the signal the gossip sync plane keys on)
# ---------------------------------------------------------------------------

def _adopt_heartbeats(r, now):
    """Heartbeat-column adoption — the composed registry replicates per
    shard, so the sharded variant drives shard 0's AnchorRegistry."""
    target = r if isinstance(r, AnchorRegistry) else r.shards[0]
    target.adopt_heartbeats(target.export_heartbeats() + 1.0)


# Concrete invocations per mutator method: {method: [(id, call, bumps)]}.
# Every mutating registry API must bump `version` (monolithic) / the
# per-shard version vector (sharded), and every no-op path must leave it
# untouched — otherwise delta gossip either misses updates or re-ships
# clean shards forever. COVERAGE is no longer hand-kept: the key set is
# checked against the analyzer-derived mutator set (repro.analysis
# classifies AnchorRegistry's AST), so a new mutating method fails
# test_covers_every_analyzer_derived_mutator until a scenario lands here.
MUTATOR_SCENARIOS = {
    "set_trust": [
        ("set_trust", lambda r, now: r.set_trust(0, 0.42), True),
        ("set_trust_unknown", lambda r, now: r.set_trust(9_999, 0.42),
         False),
    ],
    "reset_trust": [
        ("reset_trust", lambda r, now: r.reset_trust(), True),
    ],
    "apply_report": [
        ("apply_report_success",
         lambda r, now: r.apply_report(ExecReport(
             True, [0, 5],
             [HopReport(p, 40.0, True) for p in (0, 5)])), True),
        ("apply_report_failure",
         lambda r, now: r.apply_report(ExecReport(
             False, [3], [HopReport(3, 200.0, False)], failed_peer=3)),
         True),
        ("apply_report_unknown_peers",
         lambda r, now: r.apply_report(ExecReport(
             True, [9_999], [HopReport(9_999, 40.0, True)])), False),
    ],
    "sweep": [
        ("sweep_expiring",
         lambda r, now: r.sweep(now + 100.0, expire_after_s=50.0), True),
        ("sweep_decaying",
         lambda r, now: r.sweep(now + 1.0, decay_rate=0.5), True),
        ("sweep_clean", lambda r, now: r.sweep(now + 1.0), False),
    ],
    "deregister": [
        ("deregister", lambda r, now: r.deregister(1), True),
        ("deregister_unknown", lambda r, now: r.deregister(9_999), False),
    ],
    "register": [
        ("register_new", lambda r, now: r.register(500, 0, 3, now=now),
         True),
    ],
    "heartbeat": [
        ("heartbeat", lambda r, now: r.heartbeat(0, now + 0.1), False),
    ],
    "adopt_state": [
        ("adopt_state_roundtrip",
         lambda r, now: r.adopt_state(r.export_state()), True),
    ],
    "adopt_heartbeats": [
        ("adopt_heartbeats", _adopt_heartbeats, False),
    ],
}

_CASES = [(method, sid, call, bumps)
          for method, scenarios in sorted(MUTATOR_SCENARIOS.items())
          for sid, call, bumps in scenarios]


class TestVersionBumpContract:
    def test_covers_every_analyzer_derived_mutator(self):
        """The scenario table and the static analyzer must agree on what
        a mutator is — the hand-kept list this replaces let new mutators
        silently dodge the contract."""
        from repro.analysis import registry_mutators
        derived = registry_mutators()
        assert set(MUTATOR_SCENARIOS) == set(derived), (
            f"scenario table out of sync with AnchorRegistry: "
            f"missing={sorted(set(derived) - set(MUTATOR_SCENARIOS))} "
            f"stale={sorted(set(MUTATOR_SCENARIOS) - set(derived))}")

    def test_bump_expectations_match_classifier(self):
        """Heartbeat-only mutators never bump; every other mutator has at
        least one scenario that must."""
        from repro.analysis import registry_mutator_info
        info = registry_mutator_info()
        for method, scenarios in MUTATOR_SCENARIOS.items():
            if info[method].heartbeat_only:
                assert not any(b for _, _, b in scenarios), \
                    f"{method} is heartbeat-exempt but a scenario bumps"
            else:
                assert any(b for _, _, b in scenarios), \
                    f"{method} mutates records but no scenario bumps"

    @pytest.mark.parametrize("shards", [1, 4])
    @pytest.mark.parametrize(
        "method,name,mutate,bumps", _CASES, ids=[c[1] for c in _CASES])
    def test_mutators_bump_versions_noops_do_not(self, gcfg, shards,
                                                 method, name, mutate,
                                                 bumps):
        from repro.sync.gossip import registry_version_vector
        reg = make_registry(gcfg, shards=shards)
        populate(reg)
        now = 5.0
        reg.heartbeat_all(range(48), now)
        before = registry_version_vector(reg)
        mutate(reg, now)
        after = registry_version_vector(reg)
        assert (after != before) == bumps, \
            f"{name}: version vector {before} -> {after}, " \
            f"expected {'a bump' if bumps else 'no change'}"
