"""Unit tests for sharding rules, roofline parsing, and XLA-path attention
equivalences (no multi-device needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_shape
from repro.launch import roofline as rl
from repro.models.api import build_model


class TestParamSpecs:
    @pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen3-moe-30b-a3b",
                                      "rwkv6-1.6b", "zamba2-2.7b",
                                      "whisper-large-v3"])
    def test_specs_match_tree_ranks(self, arch):
        from repro.distributed.sharding import param_pspecs
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        specs = param_pspecs(params)
        flat_p = jax.tree_util.tree_leaves(params)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: hasattr(x, "index"))
        assert len(flat_p) == len(flat_s)
        for p, s in zip(flat_p, flat_s):
            assert len(s) == p.ndim, (s, p.shape)

    def test_serving_layout_strips_data_axis(self):
        from repro.distributed.sharding import param_pspecs
        cfg = get_config("tinyllama-1.1b").reduced()
        model = build_model(cfg)
        params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        train = jax.tree_util.tree_leaves(
            param_pspecs(params), is_leaf=lambda x: hasattr(x, "index"))
        serve = jax.tree_util.tree_leaves(
            param_pspecs(params, serving=True),
            is_leaf=lambda x: hasattr(x, "index"))
        assert any("data" in str(s) for s in train)
        assert not any("data" in str(s) for s in serve)
        assert any("model" in str(s) for s in serve)  # TP retained


class TestRooflineParsing:
    HLO = """
  %ag = bf16[8,128]{1,0} all-gather(bf16[1,128]{1,0} %p0), dimensions={0}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %x), to_apply=%add
  %rs = f32[32]{0} reduce-scatter(f32[256]{0} %y), dimensions={0}
  %cp = bf16[64,64]{1,0} collective-permute(bf16[64,64]{1,0} %z)
  %a2a = s32[16]{0} all-to-all(s32[16]{0} %w), dimensions={0}
"""

    def test_wire_bytes(self):
        w = rl.collective_wire_bytes(self.HLO)
        assert w["all-gather"] == 8 * 128 * 2          # 1x result
        assert w["all-reduce"] == 256 * 4 * 2          # ring 2x
        assert w["reduce-scatter"] == 32 * 4
        assert w["collective-permute"] == 64 * 64 * 2
        assert w["all-to-all"] == 16 * 4
        assert w["num_ops"] == 5

    def test_model_flops_kind_factors(self):
        cfg = get_config("tinyllama-1.1b")
        tr = rl.model_flops(cfg, get_shape("train_4k"))
        # same token count at train vs an equivalent prefill => 3x
        from repro.configs.base import ShapeConfig
        pf = rl.model_flops(cfg, ShapeConfig("x", 4096, 256, "prefill"))
        assert tr == pytest.approx(3 * pf)

    def test_moe_uses_active_params(self):
        cfg = get_config("qwen3-moe-30b-a3b")
        f = rl.model_flops(cfg, get_shape("train_4k"))
        n_active = cfg.active_param_count()
        toks = 4096 * 256
        assert f == pytest.approx(6.0 * n_active * toks)

    def test_attention_flops_quadratic(self):
        cfg = get_config("tinyllama-1.1b")
        a32 = rl.attention_flops(cfg, get_shape("prefill_32k"))
        from repro.configs.base import ShapeConfig
        a16 = rl.attention_flops(cfg, ShapeConfig("x", 16384, 32, "prefill"))
        assert a32 == pytest.approx(4 * a16)

    def test_ssm_has_no_attention_term(self):
        cfg = get_config("rwkv6-1.6b")
        assert rl.attention_flops(cfg, get_shape("prefill_32k")) == 0.0


class TestCacheSpecs:
    def _mesh(self):
        """Spec construction only needs axis names/sizes — fake a 16x16
        production mesh (a real one needs 256 devices)."""
        class FakeMesh:
            axis_names = ("data", "model")
            devices = np.zeros((16, 16))
        return FakeMesh()

    def test_mqa_cache_seq_sharded_on_model(self):
        from repro.distributed.sharding import cache_pspecs
        from repro.models.api import make_cache
        cfg = get_config("granite-34b")  # kv=1
        cache = jax.eval_shape(lambda: make_cache(cfg, 128, 1024))
        specs = cache_pspecs(self._mesh(), cfg, cache)
        assert "model" in str(specs["k"][2])   # sequence axis
        assert str(specs["k"][3]) == "None"    # 1 kv head unsharded

    def test_batch1_long_context_seq_on_data(self):
        from repro.distributed.sharding import cache_pspecs
        from repro.models.api import make_cache
        cfg = get_config("zamba2-2.7b")  # kv=32 heads
        cache = jax.eval_shape(lambda: make_cache(cfg, 1, 4096))
        specs = cache_pspecs(self._mesh(), cfg, cache)
        assert "data" in str(specs["k"][2])
        assert "model" in str(specs["k"][3])


class TestAttentionEquivalence:
    def test_chunked_equals_direct(self):
        from repro.models.attention import (attention_chunked,
                                            attention_direct)
        key = jax.random.PRNGKey(0)
        B, S, Hq, Hkv, D = 2, 128, 4, 2, 32
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, Hq, D))
        k = jax.random.normal(ks[1], (B, S, Hkv, D))
        v = jax.random.normal(ks[2], (B, S, Hkv, D))
        for causal in (True, False):
            for unroll in (True, False):
                a = attention_direct(q, k, v, causal=causal)
                b = attention_chunked(q, k, v, causal=causal, chunk=32,
                                      unroll=unroll)
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=2e-5)

    def test_window_matches_masked_direct(self):
        from repro.models.attention import attention_direct
        key = jax.random.PRNGKey(1)
        B, S, H, D = 1, 64, 2, 16
        ks = jax.random.split(key, 3)
        q, k, v = (jax.random.normal(kk, (B, S, H, D)) for kk in ks)
        win = attention_direct(q, k, v, causal=True, window=8)
        # reference: windowed == causal with manual band mask applied
        from repro.kernels.ref import attention_ref
        scale_ref = attention_ref(q, k, v, causal=True)
        assert not np.allclose(np.asarray(win), np.asarray(scale_ref),
                               atol=1e-3)  # window actually restricts

    def test_rope_matches_complex_rotation(self):
        from repro.models.rope import apply_rotary, rope_angles
        B, S, H, D = 1, 8, 1, 8
        x = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D))
        ang = rope_angles(jnp.arange(S), D, 10_000.0)[None]
        out = apply_rotary(x, ang)
        # complex reference: (x1 + i x2) * e^{i theta}
        x1, x2 = np.asarray(x[..., :D // 2]), np.asarray(x[..., D // 2:])
        zc = (x1 + 1j * x2) * np.exp(1j * np.asarray(ang))[:, :, None, :]
        want = np.concatenate([zc.real, zc.imag], -1)
        np.testing.assert_allclose(np.asarray(out), want, atol=1e-5)


class TestSeqParallelDecode:
    def test_sp_path_matches_reference(self):
        """The flash-decoding-layout path (grouped einsum, no KV repeat)
        must equal the reference decode attention on a single device."""
        from repro.models.attention import _attention_decode_sp
        from repro.kernels.ref import decode_attention_ref
        key = jax.random.PRNGKey(3)
        B, S, Hq, Hkv, D = 2, 128, 8, 1, 32   # MQA, the granite case
        ks = jax.random.split(key, 4)
        q = jax.random.normal(ks[0], (B, 1, Hq, D))
        ck = jax.random.normal(ks[1], (B, S, Hkv, D))
        cv = jax.random.normal(ks[2], (B, S, Hkv, D))
        kv_len = jax.random.randint(ks[3], (B,), 1, S + 1)
        out = _attention_decode_sp(q, ck, cv, kv_len=kv_len)
        want = decode_attention_ref(q[:, 0], ck, cv, kv_len)[:, None]
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)
