"""Simulator tests: paper testbed construction, workload metrics, fault
injection (crashes + partitions), honey-pot isolation dynamics."""
import pytest

from repro.sim.testbed import build_paper_testbed, build_scaling_testbed
from repro.sim.workload import run_workload


class TestTestbed:
    def test_336_peers_all_stages_covered(self):
        bed = build_paper_testbed(seed=0)
        assert len(bed.peers) == 336
        # every shard granularity covers [0, 36)
        for size in (3, 6, 9):
            covered = set()
            for p in bed.peers.values():
                if p.num_layers == size:
                    covered.add((p.layer_start, p.layer_end))
            assert covered == {(s, s + size) for s in range(0, 36, size)}

    def test_profiles_present(self):
        bed = build_paper_testbed(seed=0)
        for name in ("honeypot", "turtle", "golden"):
            assert len(bed.peers_by_profile(name)) > 0

    def test_profile_parameters_in_paper_ranges(self):
        bed = build_paper_testbed(seed=0)
        for p in bed.peers_by_profile("honeypot"):
            assert 0.20 <= p.p_fail <= 0.35
        for p in bed.peers_by_profile("golden"):
            assert p.p_fail == 0.0 and 20 <= p.net_delay_ms <= 40
        for p in bed.peers_by_profile("turtle"):
            assert p.p_fail == pytest.approx(0.001)
            assert 150 <= p.net_delay_ms <= 300

    def test_crash_expires_via_ttl(self):
        bed = build_paper_testbed(seed=0)
        victim = next(iter(bed.peers))
        bed.crash_peers([victim])
        bed.advance(bed.cfg.node_ttl_s + bed.cfg.heartbeat_s + 1)
        t = bed.anchor.snapshot(bed.now)
        assert not bool(t.alive[t.index_of(victim)])
        alive_frac = t.alive.mean()
        assert alive_frac > 0.9  # others keep heartbeating

    def test_partition_heals(self):
        bed = build_paper_testbed(seed=0)
        some = list(bed.peers)[:50]
        bed.partition(some)
        bed.advance(bed.cfg.node_ttl_s + 3)
        t = bed.anchor.snapshot(bed.now)
        assert not any(t.alive[t.index_of(p)] for p in some)
        bed.heal_partition()
        bed.advance(bed.cfg.heartbeat_s + 1)
        t = bed.anchor.snapshot(bed.now)
        assert all(t.alive[t.index_of(p)] for p in some)


class TestWorkload:
    def test_gtrac_beats_sp_and_isolates_honeypots(self):
        bed = build_paper_testbed(seed=3)
        run_workload(bed, "gtrac", n_requests=15, l_tok=5)       # warmup
        g = run_workload(bed, "gtrac", n_requests=20, l_tok=10,
                         request_id_base=100)
        bed2 = build_paper_testbed(seed=3)
        run_workload(bed2, "sp", n_requests=15, l_tok=5)
        s = run_workload(bed2, "sp", n_requests=20, l_tok=10,
                         request_id_base=100)
        assert g.ssr > s.ssr
        # honeypots that failed must sit below the trust floor now
        struck = [r for r in bed.anchor.peers.values() if r.failures > 0]
        assert struck, "workload should have triggered failures"
        assert all(r.trust < bed.cfg.trust_floor for r in struck)

    def test_request_survives_mid_run_crash(self):
        """Node failures during service: repair + rerouting keep SSR high."""
        bed = build_paper_testbed(seed=4)
        run_workload(bed, "gtrac", n_requests=10, l_tok=5)
        golden = [p.peer_id for p in bed.peers_by_profile("golden")][:30]
        bed.crash_peers(golden)
        bed.advance(bed.cfg.node_ttl_s + 3)
        stats = run_workload(bed, "gtrac", n_requests=15, l_tok=10,
                             request_id_base=500)
        assert stats.ssr >= 0.6  # degraded but robust (paper's claim)

    def test_wilson_ci_sane(self):
        bed = build_paper_testbed(seed=0)
        s = run_workload(bed, "mr", n_requests=10, l_tok=3)
        lo, hi = s.wilson_ci()
        assert 0.0 <= lo <= s.ssr <= hi <= 1.0

    def test_scaling_testbed_sizes(self):
        for n in (50, 200):
            bed = build_scaling_testbed(n, seed=0)
            assert len(bed.peers) == n
