"""Gossip sync plane (repro.sync): delta protocol round-trips, seeker
parity vs anchor-composed snapshots, scheduler fanout/anti-entropy,
staleness-bounded routing, partition recovery (PR 4), and the epidemic
seeker→seeker relay plane (PR 5)."""
import gc
import math

import numpy as np
import pytest

from repro.configs.base import GTRACConfig
from repro.core.planner import RoutePlanner, plan_route
from repro.core.sharding import ShardedAnchorRegistry, make_registry
from repro.core.types import ExecReport, HopReport
from repro.serving.batch_router import BatchRouter
from repro.sim.testbed import build_scaling_testbed, simulate_partition
from repro.sync.delta import (
    DeltaGapError,
    apply_delta,
    empty_state,
    full_delta,
    make_delta,
    state_wire_bytes,
)
from repro.sync.gossip import (
    GossipPublisher,
    make_sync_plane,
    registry_shard_state,
    registry_version_vector,
)
from repro.sync.relay import RelayTopology
from repro.sync.seeker import APPLIED, DUPLICATE, SeekerCache

from _hyp import given, settings, st

L = 12


def populate(reg, n=48, seed=1, now=0.0):
    rng = np.random.default_rng(seed)
    for pid in range(n):
        s = (pid % 4) * 3
        reg.register(pid, s, s + 3, now=now, profile="golden",
                     trust=float(rng.uniform(0.5, 1.0)),
                     latency_ms=float(rng.uniform(10, 300)))
        reg.heartbeat(pid, now)
    return reg


def assert_state_equal(a, b, heartbeats=True):
    assert np.array_equal(a.peer_ids, b.peer_ids)
    assert np.array_equal(a.layer_start, b.layer_start)
    assert np.array_equal(a.layer_end, b.layer_end)
    assert np.array_equal(a.trust, b.trust)        # bit-equal, not approx
    assert np.array_equal(a.latency_ms, b.latency_ms)
    assert np.array_equal(a.successes, b.successes)
    assert np.array_equal(a.failures, b.failures)
    assert np.array_equal(a.seq, b.seq)
    assert list(a.profiles) == list(b.profiles)
    if heartbeats:
        assert np.array_equal(a.last_heartbeat, b.last_heartbeat)


def assert_tables_equal(ta, ts):
    assert np.array_equal(ta.peer_ids, ts.peer_ids)
    assert np.array_equal(ta.layer_start, ts.layer_start)
    assert np.array_equal(ta.layer_end, ts.layer_end)
    assert np.array_equal(ta.trust, ts.trust)
    assert np.array_equal(ta.latency_ms, ts.latency_ms)
    assert np.array_equal(ta.alive, ts.alive)


# ---------------------------------------------------------------------------
# Delta protocol
# ---------------------------------------------------------------------------


class TestDeltaProtocol:
    def _registry(self, gcfg, n=32):
        return populate(ShardedAnchorRegistry(gcfg, n_shards=1), n=n)

    def test_roundtrip_exact(self, gcfg):
        """apply(delta(a, b)) == b, byte for byte."""
        reg = self._registry(gcfg)
        a = registry_shard_state(reg, 0)
        reg.set_trust(3, 0.21)
        reg.deregister(7)
        reg.register(100, 0, 3, now=1.0, profile="golden")
        reg.heartbeat_all(range(0, 32, 2), 2.0)
        b = registry_shard_state(reg, 0)
        d = make_delta(a, b, base_version=1, new_version=2,
                       include_heartbeats=True)
        assert not d.is_full
        assert_state_equal(apply_delta(a, d), b)

    def test_heartbeat_only_movement_is_not_a_change(self, gcfg):
        """Steady-state heartbeat traffic must not inflate deltas: with
        diffing off (the wire default) an hb-only round is empty."""
        reg = self._registry(gcfg)
        a = registry_shard_state(reg, 0)
        reg.heartbeat_all(range(32), 9.0)
        b = registry_shard_state(reg, 0)
        d = make_delta(a, b, base_version=1, new_version=1)
        assert d.is_empty
        applied = apply_delta(a, d)
        assert_state_equal(applied, b, heartbeats=False)
        # the exact mirror is available when asked for
        d2 = make_delta(a, b, base_version=1, new_version=1,
                        include_heartbeats=True)
        assert_state_equal(apply_delta(a, d2), b)

    def test_single_change_wire_bytes_small(self, gcfg):
        reg = self._registry(gcfg, n=200)
        a = registry_shard_state(reg, 0)
        reg.set_trust(11, 0.5)
        b = registry_shard_state(reg, 0)
        d = make_delta(a, b, base_version=1, new_version=2)
        assert len(d.rows) == 1
        assert d.wire_bytes() < 0.05 * state_wire_bytes(b)

    def test_mass_change_falls_back_to_full(self, gcfg):
        """reset_trust touches every row: the delta would ship the whole
        table anyway, so it degrades to the full snapshot."""
        reg = self._registry(gcfg)
        a = registry_shard_state(reg, 0)
        reg.reset_trust()
        reg.heartbeat_all(range(32), 5.0)
        b = registry_shard_state(reg, 0)
        d = make_delta(a, b, base_version=1, new_version=2,
                       include_heartbeats=True)
        assert d.is_full
        assert_state_equal(apply_delta(a, d), b)

    def test_reregistration_moves_row_to_end(self, gcfg):
        """Deregister + register = fresh seq stamp: the delta must move
        the row to the end of the composed order, like the dict."""
        reg = self._registry(gcfg)
        a = registry_shard_state(reg, 0)
        reg.deregister(0)
        reg.register(0, 3, 6, now=1.0, profile="golden")
        b = registry_shard_state(reg, 0)
        assert int(b.peer_ids[-1]) == 0     # moved to the end
        d = make_delta(a, b, base_version=1, new_version=2,
                       include_heartbeats=True)
        assert not d.is_full
        assert_state_equal(apply_delta(a, d), b)

    def test_boot_from_empty(self, gcfg):
        reg = self._registry(gcfg)
        b = registry_shard_state(reg, 0)
        d = make_delta(empty_state(), b, base_version=-1, new_version=1,
                       include_heartbeats=True)
        assert_state_equal(apply_delta(empty_state(), d), b)


# ---------------------------------------------------------------------------
# Seeker parity: bit-identical plans vs the anchor-composed snapshot
# ---------------------------------------------------------------------------


def _mutate_registry(reg, now):
    reg.apply_report(ExecReport(True, [0, 13, 26],
                                [HopReport(p, 40.0, True)
                                 for p in (0, 13, 26)]))
    reg.apply_report(ExecReport(False, [5], [HopReport(5, 300.0, False)],
                                failed_peer=5))
    reg.set_trust(9, 0.33)
    reg.deregister(17)
    reg.register(300, 0, 3, now=now, profile="golden")
    reg.heartbeat(300, now)


class TestSeekerParity:
    @pytest.mark.parametrize("shards", [1, 4, 16])
    def test_fully_synced_plans_bit_identical(self, gcfg, shards):
        reg = populate(make_registry(gcfg, shards=shards))
        _, (seeker,), sched = make_sync_plane(reg, gcfg, now=0.0)
        ta, ts = reg.snapshot(0.5), seeker.materialize(0.5)
        assert_tables_equal(ta, ts)
        pa = RoutePlanner(L, k_best=4)
        ps = RoutePlanner(L, k_best=4)
        _, plan_a = plan_route(ta, L, gcfg, tau=0.6, planner=pa)
        _, plan_s = plan_route(ts, L, gcfg, tau=0.6, planner=ps)
        assert plan_a.feasible
        assert plan_a.chain_rows == plan_s.chain_rows
        assert plan_a.costs == plan_s.costs

    @pytest.mark.parametrize("shards", [1, 4, 16])
    def test_parity_survives_incremental_sync(self, gcfg, shards):
        """Deltas (not just boot full-syncs) reproduce the anchor table."""
        reg = populate(make_registry(gcfg, shards=shards))
        _, (seeker,), sched = make_sync_plane(reg, gcfg, now=0.0)
        now = 0.0
        for step in range(3):
            _mutate_registry(reg, now) if step == 0 else \
                reg.set_trust(2 + step, 0.4 + 0.1 * step)
            for _ in range(16):   # fanout-capped: may need several rounds
                now += gcfg.gossip_period_s
                reg.heartbeat_all([p for p in range(48) if p != 17], now)
                reg.heartbeat(300, now)
                sched.tick(now)
                if sched.converged(seeker, now, check_table=False):
                    break
            assert sched.converged(seeker, now)
            assert_tables_equal(reg.snapshot(now), seeker.materialize(now))
        assert sched.stats.deltas > 0   # really exercised the delta path

    def test_window_router_parity(self, gcfg):
        """BatchRouter windows routed from a synced seeker table are
        bit-identical to windows routed from the anchor's snapshot."""
        reg = populate(make_registry(gcfg, shards=4))
        _, (seeker,), sched = make_sync_plane(reg, gcfg, now=0.0)
        ta, ts = reg.snapshot(0.5), seeker.materialize(0.5)
        taus = [0.55, 0.7, 0.55, 0.8, 0.0]
        ra = BatchRouter(planner=RoutePlanner(L, k_best=4), cfg=gcfg,
                         total_layers=L)
        rs = BatchRouter(planner=RoutePlanner(L, k_best=4), cfg=gcfg,
                         total_layers=L)
        for rid, tau in enumerate(taus):
            ra.submit(rid, tau)
            rs.submit(rid, tau)
        plans_a = ra.route_window(ta)
        plans_s = rs.route_window(ts)
        assert plans_a.keys() == plans_s.keys()
        for rid in plans_a:
            assert plans_a[rid].chain_rows == plans_s[rid].chain_rows
            assert plans_a[rid].costs == plans_s[rid].costs

    def test_seeker_generations_keep_caches_warm(self, gcfg):
        """Unchanged mirrors hand back the identical table object, and
        the planner's plan cache hits across windows (the zero-copy
        contract downstream caches key on)."""
        reg = populate(make_registry(gcfg, shards=4))
        _, (seeker,), sched = make_sync_plane(reg, gcfg, now=0.0)
        t1 = seeker.materialize(0.5)
        t2 = seeker.materialize(1.0)
        assert t1 is t2
        planner = RoutePlanner(L, k_best=4)
        plan_route(t1, L, gcfg, tau=0.6, planner=planner)
        plan_route(t2, L, gcfg, tau=0.6, planner=planner)
        assert planner.stats["plan_hits"] == 1
        # clean gossip rounds must not invalidate anything either
        sched.tick(2.0)
        t3 = seeker.materialize(2.5)
        assert t3 is t1


# ---------------------------------------------------------------------------
# Version gating: duplicates idempotent, gaps rejected
# ---------------------------------------------------------------------------


class TestVersionGating:
    def _plane(self, gcfg):
        reg = populate(ShardedAnchorRegistry(gcfg, n_shards=2))
        pub, (seeker,), sched = make_sync_plane(reg, gcfg, now=0.0)
        # a peer homed on shard 0, so shard-0 pulls see its mutations
        pid0 = next(p for p in reg.peers if reg.owner_of(p) == 0)
        return reg, pub, seeker, sched, pid0

    def test_duplicate_apply_is_idempotent(self, gcfg):
        reg, pub, seeker, sched, pid0 = self._plane(gcfg)
        have = seeker.version_vector[0]
        reg.set_trust(pid0, 0.5)
        d = pub.pull(0, have)
        assert seeker.apply(d, 1.0) == APPLIED
        state = seeker._states[0]
        assert seeker.apply(d, 2.0) == DUPLICATE
        assert seeker._states[0] is state          # untouched
        assert seeker.version_vector == registry_version_vector(reg)

    def test_out_of_order_older_delta_is_duplicate(self, gcfg):
        reg, pub, seeker, sched, pid0 = self._plane(gcfg)
        v0 = seeker.version_vector[0]
        reg.set_trust(pid0, 0.5)
        d1 = pub.pull(0, v0)
        reg.set_trust(pid0, 0.7)
        d2 = pub.pull(0, d1.new_version)
        assert seeker.apply(d1, 1.0) == APPLIED
        assert seeker.apply(d2, 1.0) == APPLIED
        trust = seeker._states[0].trust.copy()
        assert seeker.apply(d1, 2.0) == DUPLICATE   # stale replay
        assert np.array_equal(seeker._states[0].trust, trust)

    def test_version_gap_raises(self, gcfg):
        reg, pub, seeker, sched, pid0 = self._plane(gcfg)
        v0 = seeker.version_vector[0]
        reg.set_trust(pid0, 0.5)
        d1 = pub.pull(0, v0)
        reg.set_trust(pid0, 0.7)
        d2 = pub.pull(0, d1.new_version)
        with pytest.raises(DeltaGapError):
            seeker.apply(d2, 1.0)                   # d1 never arrived
        assert seeker.stats.gaps == 1
        # anti-entropy repairs the gap
        seeker.apply(pub.full(0), 1.0)
        assert seeker.version_vector[0] == \
            registry_version_vector(reg)[0]

    def test_same_version_full_sync_refreshes_liveness(self, gcfg):
        """Anti-entropy against a quiescent shard (version unchanged,
        heartbeats moved) must adopt the fresh liveness column and reset
        the staleness clocks — not bounce as a duplicate. Regression:
        a healed seeker used to reject these ships and mark every live
        peer TTL-dead on its next materialize."""
        cfg = GTRACConfig(gossip_hb_refresh_frac=0.0)
        reg = populate(ShardedAnchorRegistry(cfg, n_shards=2))
        pub, (seeker,), sched = make_sync_plane(reg, cfg, now=0.0)
        now = 2.0 * cfg.node_ttl_s          # way past the boot TTL
        reg.heartbeat_all(range(48), now)   # peers alive at the anchor
        assert seeker.apply(pub.full(0), now) == APPLIED
        assert seeker.apply(pub.full(1), now) == APPLIED
        assert np.all(seeker.staleness(now) == 0.0)
        ta, ts = reg.snapshot(now), seeker.materialize(now)
        assert ta.alive.all() and ts.alive.all()
        assert_tables_equal(ta, ts)

    def test_full_snapshot_applies_on_any_base(self, gcfg):
        reg, pub, seeker, sched, pid0 = self._plane(gcfg)
        rng = np.random.default_rng(0)
        for _ in range(4):
            reg.set_trust(pid0, float(rng.uniform()))
        assert seeker.apply(pub.full(0), 1.0) == APPLIED
        assert sched.converged(seeker, 1.0, check_table=False)


# ---------------------------------------------------------------------------
# Scheduler: fanout cap, clean rounds, anti-entropy after history loss
# ---------------------------------------------------------------------------


class TestScheduler:
    def test_fanout_caps_pulls_per_round(self, gcfg):
        reg = populate(ShardedAnchorRegistry(gcfg, n_shards=8), n=64)
        pub, (seeker,), sched = make_sync_plane(reg, gcfg, now=0.0)
        sched.fanout = 2
        for pid in range(64):          # dirty every shard
            reg.set_trust(pid, 0.6)
        shipped0 = sched.stats.deltas + sched.stats.full_syncs
        sched.tick(1.0)
        assert (sched.stats.deltas + sched.stats.full_syncs
                - shipped0) <= 2
        assert sched.stats.deferred > 0
        for r in range(8):             # the rest drain over later rounds
            if sched.converged(seeker, 1.0 + r, check_table=False):
                break
            sched.tick(1.0 + r)
        assert sched.converged(seeker, 10.0)

    def test_clean_round_ships_nothing(self, gcfg):
        reg = populate(ShardedAnchorRegistry(gcfg, n_shards=4))
        pub, (seeker,), sched = make_sync_plane(reg, gcfg, now=0.0)
        d0, f0 = sched.stats.deltas, sched.stats.full_syncs
        sched.tick(1.0)
        assert (sched.stats.deltas, sched.stats.full_syncs) == (d0, f0)
        # a clean observation still refreshes the staleness clock
        assert seeker.staleness(1.0).max() == 0.0

    def test_history_eviction_forces_anti_entropy(self, gcfg):
        """A seeker partitioned past the publisher's history depth gets a
        full shard snapshot, not a broken delta chain."""
        cfg = GTRACConfig(gossip_history=1)
        reg = populate(ShardedAnchorRegistry(cfg, n_shards=2))
        pub, (seeker,), sched = make_sync_plane(reg, cfg, now=0.0)
        pid0 = next(p for p in reg.peers if reg.owner_of(p) == 0)
        sched.partition(seeker, [0])
        for i in range(4):             # several version bumps while cut off
            reg.set_trust(pid0, 0.4 + 0.1 * i)
            pub.shard_state(0)         # each export evicts the previous
        sched.heal(seeker, [0])
        full0 = sched.stats.full_syncs
        sched.tick(1.0)
        assert sched.stats.full_syncs > full0
        assert sched.converged(seeker, 1.0)

    def test_maybe_tick_respects_period(self, gcfg):
        reg = populate(ShardedAnchorRegistry(gcfg, n_shards=2))
        pub, (seeker,), sched = make_sync_plane(reg, gcfg, now=0.0)
        assert sched.maybe_tick(0.0)
        assert not sched.maybe_tick(gcfg.gossip_period_s * 0.5)
        assert sched.maybe_tick(gcfg.gossip_period_s * 1.5)


# ---------------------------------------------------------------------------
# Staleness-bounded routing
# ---------------------------------------------------------------------------


class TestStalenessRouting:
    def _plane(self, cfg):
        reg = populate(ShardedAnchorRegistry(cfg, n_shards=4))
        return reg, *make_sync_plane(reg, cfg, now=0.0)[1:]

    def test_fresh_cache_routes_on_the_base_table(self):
        cfg = GTRACConfig(gossip_stale_margin=0.05)
        reg = populate(ShardedAnchorRegistry(cfg, n_shards=4))
        _, (seeker,), sched = make_sync_plane(reg, cfg, now=0.0)
        assert seeker.routing_view(0.5) is seeker.materialize(0.5)

    def test_stale_shards_lose_routing_trust(self):
        cfg = GTRACConfig(gossip_stale_margin=0.05,
                          gossip_stale_margin_max=0.3)
        reg = populate(ShardedAnchorRegistry(cfg, n_shards=4))
        _, (seeker,), sched = make_sync_plane(reg, cfg, now=0.0)
        sched.partition(seeker, [0, 1])
        now = 0.0
        for _ in range(4):
            now += cfg.gossip_period_s
            reg.heartbeat_all(range(48), now)
            sched.tick(now)
        base = seeker.materialize(now)
        adj = seeker.routing_view(now)
        assert adj is not base
        assert adj.source_id != base.source_id
        rounds = seeker.staleness_rounds(now)
        assert rounds[[0, 1]].min() >= 4
        assert np.all(rounds[[2, 3]] <= 1)
        stale_rows = np.isin(base.peer_ids,
                             [pid for pid in range(48)
                              if reg.owner_of(pid) in (0, 1)])
        dock = base.trust - adj.trust
        expected = np.minimum(0.05 * rounds.max(), 0.3)
        assert np.allclose(dock[stale_rows], expected)
        assert np.all(dock[~stale_rows] == 0.0)   # fresh shards untouched

    def test_stale_trust_discounts_toward_init(self):
        """gossip_stale_decay mirrors the anchor sweep's decay law on the
        seeker side: unconfirmed trust drifts back to the prior."""
        cfg = GTRACConfig(init_trust=0.8, gossip_stale_decay=0.1)
        reg = populate(ShardedAnchorRegistry(cfg, n_shards=2))
        _, (seeker,), sched = make_sync_plane(reg, cfg, now=0.0)
        sched.partition(seeker)
        now = 20.0
        base = seeker.materialize(now)
        adj = seeker.routing_view(now)
        f = np.exp(-0.1 * seeker.staleness(now))
        expected = 0.8 + (base.trust - 0.8) * f[0]
        assert np.allclose(adj.trust, np.clip(expected, 0.0, 1.0))
        # closer to the prior than the raw estimate everywhere
        assert np.all(np.abs(adj.trust - 0.8)
                      <= np.abs(base.trust - 0.8) + 1e-12)

    def test_stale_routing_is_conservative(self):
        """A peer riding just above the trust floor on a stale shard must
        fall out of the feasible set — the partitioned seeker demands a
        margin it cannot confirm."""
        cfg = GTRACConfig(gossip_stale_margin=0.05,
                          gossip_stale_margin_max=0.5)
        reg = populate(ShardedAnchorRegistry(cfg, n_shards=1))
        _, (seeker,), sched = make_sync_plane(reg, cfg, now=0.0)
        tau = 0.6
        base = seeker.materialize(0.0)
        fresh_mask = base.alive & (base.trust >= tau)
        assert fresh_mask.sum() > 0
        sched.partition(seeker)
        now = 10 * cfg.gossip_period_s
        adj = seeker.routing_view(now)
        stale_mask = adj.alive & (adj.trust >= tau)
        assert stale_mask.sum() < fresh_mask.sum()
        assert not np.any(stale_mask & ~fresh_mask)   # never less strict

    def test_routing_view_cached_per_round(self):
        cfg = GTRACConfig(gossip_stale_margin=0.05)
        reg = populate(ShardedAnchorRegistry(cfg, n_shards=2))
        _, (seeker,), sched = make_sync_plane(reg, cfg, now=0.0)
        sched.partition(seeker)
        t1 = seeker.routing_view(3.0)
        t2 = seeker.routing_view(3.5)    # same stale-round vector
        assert t1 is t2
        t3 = seeker.routing_view(3.0 + 2 * cfg.gossip_period_s)
        assert t3 is not t1
        assert t3.version != t1.version


# ---------------------------------------------------------------------------
# Partition simulation (sim/testbed.py)
# ---------------------------------------------------------------------------


class TestPartitionRecovery:
    def test_partition_heal_convergence(self, gcfg):
        cfg = GTRACConfig(gossip_fanout=2, gossip_stale_margin=0.02)
        bed = build_scaling_testbed(96, cfg=cfg, seed=3, shards=4)
        _, (seeker,), sched = make_sync_plane(bed.anchor, cfg, now=bed.now)
        pids = sorted(bed.peers)

        def churn(bed):
            chain = [int(p) for p in pids[:3]]
            bed.anchor.apply_report(ExecReport(
                True, chain, [HopReport(p, 60.0, True) for p in chain]))

        stats = simulate_partition(bed, sched, seeker, [0, 1],
                                   partition_windows=4, window_s=2.0,
                                   mutate=churn)
        assert stats.converged
        assert stats.rounds_to_convergence >= 0
        assert stats.max_stale_rounds >= 3     # it really went stale
        ta = bed.anchor.snapshot(bed.now)
        assert_tables_equal(ta, seeker.materialize(bed.now))
        # post-heal the routing view is the base table again (no margin)
        assert seeker.routing_view(bed.now) is seeker.materialize(bed.now)

    def test_staleness_grows_only_on_blocked_shards(self, gcfg):
        reg = populate(ShardedAnchorRegistry(gcfg, n_shards=4))
        _, (seeker,), sched = make_sync_plane(reg, gcfg, now=0.0)
        sched.partition(seeker, [2])
        now = 0.0
        for _ in range(3):
            now += gcfg.gossip_period_s
            sched.tick(now)
        ages = seeker.staleness(now)
        assert ages[2] == pytest.approx(3 * gcfg.gossip_period_s)
        assert np.all(ages[[0, 1, 3]] == 0.0)


# ---------------------------------------------------------------------------
# Property tests: random mutation scripts (hypothesis)
# ---------------------------------------------------------------------------

N_PROP_PEERS = 24


def _apply_op(reg, op, now, next_pid):
    """One scripted registry mutation. op = (kind, a, b) small ints."""
    kind, a, b = op[0] % 6, op[1], op[2]
    pids = list(reg.peers)
    if kind == 0:                                   # register fresh
        pid = next_pid[0]
        next_pid[0] += 1
        reg.register(pid, (a % 4) * 3, (a % 4) * 3 + 3, now=now,
                     profile="golden", trust=0.5 + (b % 50) / 100.0)
        reg.heartbeat(pid, now)
    elif kind == 1 and pids:                        # deregister
        reg.deregister(pids[a % len(pids)])
    elif kind == 2 and pids:                        # out-of-band trust write
        reg.set_trust(pids[a % len(pids)], (b % 100) / 100.0)
    elif kind == 3 and pids:                        # execution report
        chain = [pids[a % len(pids)], pids[b % len(pids)]]
        ok = (a + b) % 2 == 0
        reg.apply_report(ExecReport(
            ok, chain if ok else [],
            [HopReport(p, 20.0 + b, True) for p in chain],
            failed_peer=None if ok else chain[0]))
    elif kind == 4 and pids:                        # heartbeat
        reg.heartbeat(pids[a % len(pids)], now)
    else:                                           # decaying sweep
        reg.sweep(now, decay_rate=0.05)


def _sync_round(reg, pub, seeker, now, prev_deltas):
    """Delta-sync every dirty shard; returns the deltas shipped."""
    vv = registry_version_vector(reg)
    shipped = []
    for s in range(pub.n_shards):
        have = seeker.version_vector[s]
        if vv[s] == have:
            continue
        d = pub.pull(s, have)
        assert seeker.apply(d, now) == APPLIED
        shipped.append(d)
        # replay is idempotent: non-full deltas bounce as duplicates; a
        # full snapshot at the mirrored version is accepted as a
        # liveness refresh but leaves the state object untouched (its
        # heartbeat column is identical)
        st_before = seeker._states[s]
        assert seeker.apply(d, now) == \
            (APPLIED if d.is_full else DUPLICATE)
        assert seeker._states[s] is st_before
    # out-of-order replay of an older round's delta is rejected or
    # idempotent: never silently merged (full snapshots AT the mirrored
    # version count as liveness refreshes, not merges)
    for d in prev_deltas:
        cur = seeker.version_vector[d.shard]
        if d.is_full and d.new_version == cur:
            assert seeker.apply(d, now) == APPLIED
        elif d.new_version <= cur:
            assert seeker.apply(d, now) == DUPLICATE
        else:
            with pytest.raises(DeltaGapError):
                seeker.apply(d, now)
    return shipped


def _run_mutation_script(script, n_shards=4):
    """Drive a sharded registry through a mutation script, delta-syncing
    after every round; per-shard mirrors must equal the anchor's state
    byte-for-byte at every round boundary (deltas compose across
    rounds), and replays/gaps must be handled."""
    cfg = GTRACConfig(ttl_expire_factor=4.0)
    reg = populate(ShardedAnchorRegistry(cfg, n_shards=n_shards),
                   n=N_PROP_PEERS, seed=2)
    pub = GossipPublisher(reg, cfg)
    seeker = SeekerCache(cfg, n_shards, now=0.0)
    for s in range(n_shards):
        seeker.apply(pub.full(s), 0.0)
    next_pid = [1000]
    now = 0.0
    prev = []
    for rnd in script:
        now += 1.0
        for op in rnd:
            _apply_op(reg, op, now, next_pid)
        prev = _sync_round(reg, pub, seeker, now, prev)
        for s in range(n_shards):
            a = registry_shard_state(reg, s)
            b = seeker._states[s]
            # exact mirror modulo heartbeat drift (hb is not a diffed
            # column; see sync/delta.py)
            assert np.array_equal(a.peer_ids, b.peer_ids)
            assert np.array_equal(a.trust, b.trust)
            assert np.array_equal(a.latency_ms, b.latency_ms)
            assert np.array_equal(a.seq, b.seq)
            assert np.array_equal(a.successes, b.successes)
            assert np.array_equal(a.failures, b.failures)
    assert seeker.version_vector == registry_version_vector(reg)


_op = st.tuples(st.integers(0, 11), st.integers(0, 63), st.integers(0, 99))


class TestDeltaProperties:
    @given(script=st.lists(st.lists(_op, max_size=6), max_size=5))
    @settings(max_examples=30, deadline=None)
    def test_random_mutation_scripts(self, script):
        _run_mutation_script(script)

    def test_fixed_random_scripts(self):
        """Deterministic twin of the property test (runs when hypothesis
        is unavailable): a few seeded random scripts through the same
        harness."""
        rng = np.random.default_rng(7)
        for _ in range(4):
            script = [[(int(rng.integers(12)), int(rng.integers(64)),
                        int(rng.integers(100)))
                       for _ in range(int(rng.integers(1, 7)))]
                      for _ in range(int(rng.integers(1, 6)))]
            _run_mutation_script(script)


# ---------------------------------------------------------------------------
# Epidemic seeker→seeker relay (sync/relay.py)
# ---------------------------------------------------------------------------


def _relay_cfg(**kw):
    base = dict(relay_enabled=True, relay_fanout=3, gossip_fanout=2,
                gossip_hb_refresh_frac=0.5)
    base.update(kw)
    return GTRACConfig(**base)


def _relay_plane(cfg, n_seekers=12, n=64, shards=8, seed=1):
    reg = populate(ShardedAnchorRegistry(cfg, n_shards=shards), n=n,
                   seed=seed)
    pub, seekers, sched = make_sync_plane(reg, cfg, n_seekers=n_seekers,
                                          now=0.0)
    return reg, pub, seekers, sched


def _churn(reg, rng, now, next_pid):
    pids = list(reg.peers)
    reg.set_trust(pids[int(rng.integers(len(pids)))],
                  float(rng.uniform(0.3, 1.0)))
    reg.apply_report(ExecReport(
        True, pids[:3], [HopReport(p, 40.0, True) for p in pids[:3]]))
    pid = next_pid[0]
    next_pid[0] += 1
    reg.register(pid, 0, 3, now=now, profile="golden")
    reg.heartbeat(pid, now)


class TestRelayTopology:
    def test_deterministic_k_regular_no_self(self):
        topo = RelayTopology(fanout=3, seed=5)
        a = topo.neighbors(16, 2)
        b = RelayTopology(fanout=3, seed=5).neighbors(16, 2)
        assert all(np.array_equal(x, y) for x, y in zip(a, b))
        for i, nb in enumerate(a):
            assert len(nb) == 3
            assert len(set(nb.tolist())) == 3
            assert i not in nb
            assert all(0 <= j < 16 for j in nb)
        c = topo.neighbors(16, 3)   # rounds draw different samples
        assert any(not np.array_equal(x, y) for x, y in zip(a, c))

    def test_small_populations_degenerate_cleanly(self):
        topo = RelayTopology(fanout=4, seed=0)
        assert [list(x) for x in topo.neighbors(1, 0)] == [[]]
        for i, nb in enumerate(topo.neighbors(3, 0)):
            assert sorted(nb.tolist()) == sorted(set(range(3)) - {i})


class TestRelayPlane:
    def test_anchor_fanout_constant_while_all_seekers_converge(self):
        """The relay contract: anchor pushes stay at gossip_fanout per
        round while every seeker converges within the epidemic bound."""
        cfg = _relay_cfg()
        reg, pub, seekers, sched = _relay_plane(cfg)
        for pid in range(0, 64, 3):
            reg.set_trust(pid, 0.6)
        now, rounds = 0.0, 0
        bound = math.ceil(math.log2(len(seekers))) + 2
        while not sched.all_converged(now) and rounds < bound:
            pushes0 = sched.stats.pushes
            now += cfg.gossip_period_s
            reg.heartbeat_all(range(64), now)
            sched.tick(now)
            rounds += 1
            assert sched.stats.pushes - pushes0 <= cfg.gossip_fanout
        assert sched.all_converged(now, check_table=True), \
            f"not converged after {rounds} rounds (bound {bound})"

    def test_relay_converged_seekers_plan_bit_identical(self, gcfg):
        """Relay-converged seekers (including ones that never talked to
        the anchor after boot) plan bit-identically to anchor-composed
        snapshots — RoutePlanner AND BatchRouter parity."""
        cfg = _relay_cfg()
        reg, pub, seekers, sched = _relay_plane(cfg, n_seekers=8,
                                                shards=4)
        _mutate_registry(reg, 0.0)
        now = 0.0
        for _ in range(math.ceil(math.log2(8)) + 2):
            now += cfg.gossip_period_s
            reg.heartbeat_all([p for p in range(48) if p != 17], now)
            reg.heartbeat(300, now)
            sched.tick(now)
            if sched.all_converged(now):
                break
        assert sched.all_converged(now, check_table=True)
        ta = reg.snapshot(now)
        pa = RoutePlanner(L, k_best=4)
        _, plan_a = plan_route(ta, L, gcfg, tau=0.6, planner=pa)
        assert plan_a.feasible
        ra = BatchRouter(planner=RoutePlanner(L, k_best=4), cfg=gcfg,
                         total_layers=L)
        for rid, tau in enumerate([0.55, 0.7, 0.0]):
            ra.submit(rid, tau)
        plans_a = ra.route_window(ta)
        for seeker in seekers:
            ts = seeker.materialize(now)
            assert_tables_equal(ta, ts)
            ps = RoutePlanner(L, k_best=4)
            _, plan_s = plan_route(ts, L, gcfg, tau=0.6, planner=ps)
            assert plan_a.chain_rows == plan_s.chain_rows
            assert plan_a.costs == plan_s.costs
            rs = BatchRouter(planner=RoutePlanner(L, k_best=4), cfg=gcfg,
                             total_layers=L)
            for rid, tau in enumerate([0.55, 0.7, 0.0]):
                rs.submit(rid, tau)
            plans_s = rs.route_window(ts)
            for rid in plans_a:
                assert plans_a[rid].chain_rows == plans_s[rid].chain_rows
                assert plans_a[rid].costs == plans_s[rid].costs

    def test_duplicate_and_out_of_order_messages_absorbed(self):
        """Replayed and stale relay messages are idempotent no-ops."""
        cfg = _relay_cfg()
        reg, pub, seekers, sched = _relay_plane(cfg, n_seekers=2,
                                                shards=2, n=32)
        s0, s1 = seekers
        relay = sched.relay
        pid0 = next(p for p in reg.peers if reg.owner_of(p) == 0)
        # two update generations applied at s0 (and recorded for relay)
        reg.set_trust(pid0, 0.5)
        sched._ship(s0, 0, 1.0)
        msg_old = relay.node(s0).message(1.0, cfg.node_ttl_s)
        reg.set_trust(pid0, 0.7)
        sched._ship(s0, 0, 2.0)
        msg_new = relay.node(s0).message(2.0, cfg.node_ttl_s)
        relay.deliver(msg_new, relay.node(s0), s1, 2.0)
        assert s1.version_vector == s0.version_vector
        vv = s1.version_vector
        state = s1._states[0]
        trust = state.trust.copy()
        dup0 = relay.stats.duplicates
        relay.deliver(msg_new, relay.node(s0), s1, 3.0)   # replay
        relay.deliver(msg_old, relay.node(s0), s1, 3.0)   # out of order
        assert s1.version_vector == vv
        assert s1._states[0] is state                     # untouched
        assert np.array_equal(s1._states[0].trust, trust)
        assert relay.stats.duplicates > dup0
        assert relay.stats.peer_full_syncs == 0

    def test_relayed_chains_inherit_sender_staleness(self):
        """A late-delivered relay chain must not reset the receiver's
        staleness clock to the delivery time: the data is only as fresh
        as the SENDER's last anchor confirmation, and a receiver still
        behind the anchor has to keep routing on a discounted view."""
        cfg = _relay_cfg()
        reg, pub, seekers, sched = _relay_plane(cfg, n_seekers=2,
                                                shards=2, n=32)
        s0, s1 = seekers
        pid0 = next(p for p in reg.peers if reg.owner_of(p) == 0)
        reg.set_trust(pid0, 0.5)
        sched._ship(s0, 0, 1.0)        # s0 confirmed shard 0 at t=1
        msg = sched.relay.node(s0).message(1.0, cfg.node_ttl_s)
        reg.set_trust(pid0, 0.9)       # anchor advances past the message
        late = 1.0 + 10 * cfg.gossip_period_s
        sched.relay.deliver(msg, sched.relay.node(s0), s1, late)
        assert s1.version_vector[0] == msg.versions[0]   # chain applied
        assert s1.version_vector[0] < registry_version_vector(reg)[0]
        assert s1.staleness_rounds(late)[0] >= 9         # pre-fix: 0

    def test_anchor_partitioned_seeker_converges_via_relay(self):
        """The new scenario class: a seeker cut off from the anchor but
        reachable by neighbors keeps converging — staleness stays
        bounded and the mirror tracks churn the whole time."""
        cfg = _relay_cfg()
        reg, pub, seekers, sched = _relay_plane(cfg)
        cut = seekers[0]
        sched.partition(cut)               # anchor leg only
        rng = np.random.default_rng(0)
        next_pid = [1000]
        now = 0.0
        for _ in range(6):                 # burst of churn, still cut off
            _churn(reg, rng, now, next_pid)
            now += cfg.gossip_period_s
            reg.heartbeat_all(list(reg.peers), now)
            sched.tick(now)
        max_stale = int(cut.staleness_rounds(now).max())
        # epidemic drain: within the relay bound of the LAST churn the
        # cut-off seeker must hold the anchor's exact state
        for _ in range(math.ceil(math.log2(len(seekers))) + 2):
            if sched.converged(cut, now):
                break
            now += cfg.gossip_period_s
            reg.heartbeat_all(list(reg.peers), now)
            sched.tick(now)
        assert sched.converged(cut, now), \
            "anchor-partitioned seeker failed to converge via relay"
        # neighbors kept it roughly current even while churn was live
        assert max_stale <= 3
        # and the relay plane really carried it (no anchor contact)
        assert sched.blocked_shards(cut) == set(range(pub.n_shards))

    def test_partition_scenario_class_via_testbed(self):
        """simulate_partition doubles as the relay scenario driver:
        converged_during_partition reports the epidemic kept the cut-off
        seeker current, and post-heal reconciliation is instant."""
        cfg = _relay_cfg(gossip_stale_margin=0.02)
        bed = build_scaling_testbed(96, cfg=cfg, seed=3, shards=4)
        pub, seekers, sched = make_sync_plane(bed.anchor, cfg,
                                              n_seekers=8, now=bed.now)
        pids = sorted(bed.peers)
        calls = [0]

        def churn(bed):
            # churn the first windows, then let the epidemic drain: the
            # during-partition convergence claim is "within the relay
            # bound of the last burst", not "instantly every round"
            calls[0] += 1
            if calls[0] > 3:
                return
            chain = [int(p) for p in pids[:3]]
            bed.anchor.apply_report(ExecReport(
                True, chain, [HopReport(p, 60.0, True) for p in chain]))

        stats = simulate_partition(bed, sched, seekers[0],
                                   list(range(4)),   # ALL anchor shards
                                   partition_windows=9, window_s=2.0,
                                   mutate=churn)
        assert stats.converged_during_partition
        assert stats.converged
        assert stats.rounds_to_convergence == 0
        assert stats.max_stale_rounds <= 3
        ta = bed.anchor.snapshot(bed.now)
        assert_tables_equal(ta, seekers[0].materialize(bed.now))

    def test_gap_repair_prefers_anchor_when_reachable(self):
        """A receiver behind every chain base anti-entropies from the
        anchor (the root of trust) when it can."""
        cfg = _relay_cfg()
        reg, pub, seekers, sched = _relay_plane(cfg, n_seekers=2,
                                                shards=2, n=32)
        s0 = seekers[0]
        pid0 = next(p for p in reg.peers if reg.owner_of(p) == 0)
        reg.set_trust(pid0, 0.5)
        sched._ship(s0, 0, 1.0)
        late = SeekerCache(cfg, 2, now=1.0)   # boot-empty: behind chains
        sched.add_seeker(late)
        msg = sched.relay.node(s0).message(1.0, cfg.node_ttl_s)
        sched.relay.deliver(msg, sched.relay.node(s0), late, 1.0,
                            anchor_pull=sched._relay_pull)
        assert sched.relay.stats.anchor_repairs >= 1
        assert sched.relay.stats.peer_full_syncs == 0
        assert sched.converged(late, 1.0)

    def test_gap_repair_falls_back_to_neighbor_mirror(self):
        """The same gap with the anchor unreachable adopts the sender's
        full shard mirror instead — and the adopted state aliases
        neither the sender nor co-receivers."""
        cfg = _relay_cfg()
        reg, pub, seekers, sched = _relay_plane(cfg, n_seekers=2,
                                                shards=2, n=32)
        s0 = seekers[0]
        pid0 = next(p for p in reg.peers if reg.owner_of(p) == 0)
        reg.set_trust(pid0, 0.5)
        sched._ship(s0, 0, 1.0)
        sched._ship(s0, 1, 1.0)
        late = SeekerCache(cfg, 2, now=1.0)
        late2 = SeekerCache(cfg, 2, now=1.0)
        sched.add_seeker(late)
        sched.add_seeker(late2)
        sched.partition(late)              # anchor unreachable
        sched.partition(late2)
        msg = sched.relay.node(s0).message(1.0, cfg.node_ttl_s)
        for rx in (late, late2):
            sched.relay.deliver(msg, sched.relay.node(s0), rx, 1.0,
                                anchor_pull=sched._relay_pull)
        assert sched.relay.stats.peer_full_syncs >= 2
        assert late.version_vector == s0.version_vector
        assert late2.version_vector == s0.version_vector
        # no aliasing between sender mirror and the two adopted copies
        assert late._states[0] is not s0._states[0]
        assert late._states[0] is not late2._states[0]
        hb_sender = s0._states[0].last_heartbeat.copy()
        hb_peer = late2._states[0].last_heartbeat.copy()
        late.refresh_heartbeats(0, np.full(len(hb_sender), 321.0), 9.0)
        assert np.array_equal(s0._states[0].last_heartbeat, hb_sender)
        assert np.array_equal(late2._states[0].last_heartbeat, hb_peer)

    def test_relay_spreads_heartbeat_leases(self):
        """Only seeds get anchor hb refreshes in relay mode; the lease
        must reach non-seeds through the epidemic before node_ttl_s."""
        cfg = _relay_cfg(gossip_fanout=1, relay_fanout=3)
        reg, pub, seekers, sched = _relay_plane(cfg, n_seekers=8,
                                                shards=2, n=32)
        now = 0.0
        # live peers heartbeat at the anchor; no shard versions move, so
        # liveness can ONLY reach non-seed seekers via hb leases
        for _ in range(10):
            now += cfg.gossip_period_s
            reg.heartbeat_all(range(32), now)
            sched.tick(now)
        assert sched.relay.stats.hb_adopted > 0
        for seeker in seekers:
            assert seeker.materialize(now).alive.all(), \
                "a seeker TTL-expired live peers (lease never arrived)"


# ---------------------------------------------------------------------------
# Gossip correctness regressions (the bugs the relay plane exposed)
# ---------------------------------------------------------------------------


class TestGossipRegressions:
    def test_full_sync_adopt_does_not_alias_publisher_history(self, gcfg):
        """Regression: the publisher stored the exported state in its
        delta history AND shipped the same object in ShardDelta.full;
        the seeker adopted it as its mirror, so an hb-refresh lease
        rebinding the mirror's liveness column mutated the publisher's
        delta base in place."""
        reg = populate(ShardedAnchorRegistry(gcfg, n_shards=1))
        pub = GossipPublisher(reg, gcfg)
        seeker = SeekerCache(gcfg, 1, now=0.0)
        d = pub.full(0)
        assert seeker.apply(d, 0.0) == APPLIED
        assert seeker._states[0] is not d.full      # defensive copy
        v = registry_version_vector(reg)[0]
        hist = pub._history[0][v]
        hb_before = hist.last_heartbeat.copy()
        # mutate the seeker mirror the way the hb-refresh lease does
        assert seeker.refresh_heartbeats(
            0, np.full(len(hist.peer_ids), 123.0), 5.0)
        assert np.array_equal(hist.last_heartbeat, hb_before), \
            "seeker mirror mutation leaked into the publisher history"
        # the history entry still produces a correct delta base
        reg.set_trust(1, 0.42)
        d2 = pub.pull(0, v)
        assert not d2.is_full
        assert seeker.apply(d2, 6.0) == APPLIED
        assert np.array_equal(seeker._states[0].trust,
                              registry_shard_state(reg, 0).trust)

    def test_sub_round_staleness_still_decays(self):
        """Regression: the per-second gossip_stale_decay was gated on
        the per-ROUND staleness being nonzero, so any staleness under
        one gossip period skipped the documented decay-per-second law."""
        cfg = GTRACConfig(init_trust=0.8, gossip_stale_decay=0.1)
        reg = populate(ShardedAnchorRegistry(cfg, n_shards=2))
        _, (seeker,), sched = make_sync_plane(reg, cfg, now=0.0)
        now = 0.5 * cfg.gossip_period_s      # HALF a round stale
        assert not seeker.staleness_rounds(now).any()
        base = seeker.materialize(now)
        adj = seeker.routing_view(now)
        assert adj is not base               # pre-fix: base came back
        f = np.exp(-0.1 * now)
        expected = 0.8 + (base.trust - 0.8) * f
        assert np.allclose(adj.trust, np.clip(expected, cfg.min_trust,
                                              cfg.max_trust))

    def test_margin_still_gates_on_whole_rounds(self):
        """The round-denominated margin must NOT fire below one round —
        only the per-second decay does."""
        cfg = GTRACConfig(gossip_stale_margin=0.05)
        reg = populate(ShardedAnchorRegistry(cfg, n_shards=2))
        _, (seeker,), sched = make_sync_plane(reg, cfg, now=0.0)
        now = 0.5 * cfg.gossip_period_s
        assert seeker.routing_view(now) is seeker.materialize(now)

    def test_partition_state_not_inherited_by_recreated_seeker(self, gcfg):
        """Regression: _blocked was keyed by id(seeker); a
        garbage-collected seeker's reused python id handed its partition
        state to a brand-new seeker. Keyed by source_id now.

        The deterministic contract (keys ARE source_ids; any fresh
        seeker starts unblocked) is asserted unconditionally. Actually
        landing a new seeker on the dead one's python id is allocator
        luck — when CPython obliges within 256 allocations the test
        exercises the original crash verbatim; when it doesn't, the
        contract assertions still pin the fix, so the test never
        skips."""
        reg = populate(ShardedAnchorRegistry(gcfg, n_shards=2))
        pub, (s0,), sched = make_sync_plane(reg, gcfg, now=0.0)
        old = SeekerCache(gcfg, 2, now=0.0)
        sched.seekers.append(old)
        sched.partition(old)
        assert sched.blocked_shards(old) == {0, 1}
        # deterministic: the key IS the stable source_id, not id()
        assert set(sched._blocked) == {old.source_id}
        old_pyid, old_sid = id(old), old.source_id
        # drop the seeker WITHOUT scheduler hygiene (the crash path)
        sched.seekers = [s for s in sched.seekers if s is not old]
        del old
        gc.collect()
        fresh = SeekerCache(gcfg, 2, now=0.0)
        keep = []
        for _ in range(256):
            if id(fresh) == old_pyid:   # the original bug's exact trigger
                break
            keep.append(fresh)
            fresh = SeekerCache(gcfg, 2, now=0.0)
        # source_ids are never recycled, so the stale entry cannot alias
        # the newcomer — python id reuse or not
        assert fresh.source_id != old_sid
        sched.seekers.append(fresh)
        assert sched.blocked_shards(fresh) == set()    # pre-fix: {0, 1}
        pushes0 = sched.stats.pushes
        sched.tick(1.0)
        assert sched.stats.pushes > pushes0
        assert sched.converged(fresh, 1.0, check_table=False)

    def test_remove_seeker_drops_all_per_seeker_state(self, gcfg):
        """Scheduler hygiene across drop/recreate cycles: partitions and
        relay nodes die with their seeker."""
        cfg = _relay_cfg()
        reg, pub, seekers, sched = _relay_plane(cfg, n_seekers=3,
                                                shards=2, n=32)
        victim = seekers[1]
        sched.partition(victim, [0])
        assert sched.blocked_shards(victim) == {0}
        sched.relay.node(victim)     # materialize a relay node
        sched.remove_seeker(victim)
        assert victim not in sched.seekers
        assert sched._blocked == {}
        assert victim.source_id not in sched.relay._nodes
        # a fresh replacement starts clean and syncs immediately
        fresh = SeekerCache(cfg, 2, now=0.0)
        sched.add_seeker(fresh)
        assert sched.blocked_shards(fresh) == set()
        reg.set_trust(next(iter(reg.peers)), 0.5)
        for r in range(4):
            sched.tick(1.0 + r)
        assert sched.converged(fresh, 4.0, check_table=False)
