"""Gossip sync plane (repro.sync): delta protocol round-trips, seeker
parity vs anchor-composed snapshots, scheduler fanout/anti-entropy,
staleness-bounded routing, and partition recovery (PR 4)."""
import numpy as np
import pytest

from repro.configs.base import GTRACConfig
from repro.core.planner import RoutePlanner, plan_route
from repro.core.sharding import ShardedAnchorRegistry, make_registry
from repro.core.types import ExecReport, HopReport
from repro.serving.batch_router import BatchRouter
from repro.sim.testbed import build_scaling_testbed, simulate_partition
from repro.sync.delta import (
    DeltaGapError,
    apply_delta,
    empty_state,
    full_delta,
    make_delta,
    state_wire_bytes,
)
from repro.sync.gossip import (
    GossipPublisher,
    make_sync_plane,
    registry_shard_state,
    registry_version_vector,
)
from repro.sync.seeker import APPLIED, DUPLICATE, SeekerCache

from _hyp import given, settings, st

L = 12


def populate(reg, n=48, seed=1, now=0.0):
    rng = np.random.default_rng(seed)
    for pid in range(n):
        s = (pid % 4) * 3
        reg.register(pid, s, s + 3, now=now, profile="golden",
                     trust=float(rng.uniform(0.5, 1.0)),
                     latency_ms=float(rng.uniform(10, 300)))
        reg.heartbeat(pid, now)
    return reg


def assert_state_equal(a, b, heartbeats=True):
    assert np.array_equal(a.peer_ids, b.peer_ids)
    assert np.array_equal(a.layer_start, b.layer_start)
    assert np.array_equal(a.layer_end, b.layer_end)
    assert np.array_equal(a.trust, b.trust)        # bit-equal, not approx
    assert np.array_equal(a.latency_ms, b.latency_ms)
    assert np.array_equal(a.successes, b.successes)
    assert np.array_equal(a.failures, b.failures)
    assert np.array_equal(a.seq, b.seq)
    assert list(a.profiles) == list(b.profiles)
    if heartbeats:
        assert np.array_equal(a.last_heartbeat, b.last_heartbeat)


def assert_tables_equal(ta, ts):
    assert np.array_equal(ta.peer_ids, ts.peer_ids)
    assert np.array_equal(ta.layer_start, ts.layer_start)
    assert np.array_equal(ta.layer_end, ts.layer_end)
    assert np.array_equal(ta.trust, ts.trust)
    assert np.array_equal(ta.latency_ms, ts.latency_ms)
    assert np.array_equal(ta.alive, ts.alive)


# ---------------------------------------------------------------------------
# Delta protocol
# ---------------------------------------------------------------------------


class TestDeltaProtocol:
    def _registry(self, gcfg, n=32):
        return populate(ShardedAnchorRegistry(gcfg, n_shards=1), n=n)

    def test_roundtrip_exact(self, gcfg):
        """apply(delta(a, b)) == b, byte for byte."""
        reg = self._registry(gcfg)
        a = registry_shard_state(reg, 0)
        reg.set_trust(3, 0.21)
        reg.deregister(7)
        reg.register(100, 0, 3, now=1.0, profile="golden")
        reg.heartbeat_all(range(0, 32, 2), 2.0)
        b = registry_shard_state(reg, 0)
        d = make_delta(a, b, base_version=1, new_version=2,
                       include_heartbeats=True)
        assert not d.is_full
        assert_state_equal(apply_delta(a, d), b)

    def test_heartbeat_only_movement_is_not_a_change(self, gcfg):
        """Steady-state heartbeat traffic must not inflate deltas: with
        diffing off (the wire default) an hb-only round is empty."""
        reg = self._registry(gcfg)
        a = registry_shard_state(reg, 0)
        reg.heartbeat_all(range(32), 9.0)
        b = registry_shard_state(reg, 0)
        d = make_delta(a, b, base_version=1, new_version=1)
        assert d.is_empty
        applied = apply_delta(a, d)
        assert_state_equal(applied, b, heartbeats=False)
        # the exact mirror is available when asked for
        d2 = make_delta(a, b, base_version=1, new_version=1,
                        include_heartbeats=True)
        assert_state_equal(apply_delta(a, d2), b)

    def test_single_change_wire_bytes_small(self, gcfg):
        reg = self._registry(gcfg, n=200)
        a = registry_shard_state(reg, 0)
        reg.set_trust(11, 0.5)
        b = registry_shard_state(reg, 0)
        d = make_delta(a, b, base_version=1, new_version=2)
        assert len(d.rows) == 1
        assert d.wire_bytes() < 0.05 * state_wire_bytes(b)

    def test_mass_change_falls_back_to_full(self, gcfg):
        """reset_trust touches every row: the delta would ship the whole
        table anyway, so it degrades to the full snapshot."""
        reg = self._registry(gcfg)
        a = registry_shard_state(reg, 0)
        reg.reset_trust()
        reg.heartbeat_all(range(32), 5.0)
        b = registry_shard_state(reg, 0)
        d = make_delta(a, b, base_version=1, new_version=2,
                       include_heartbeats=True)
        assert d.is_full
        assert_state_equal(apply_delta(a, d), b)

    def test_reregistration_moves_row_to_end(self, gcfg):
        """Deregister + register = fresh seq stamp: the delta must move
        the row to the end of the composed order, like the dict."""
        reg = self._registry(gcfg)
        a = registry_shard_state(reg, 0)
        reg.deregister(0)
        reg.register(0, 3, 6, now=1.0, profile="golden")
        b = registry_shard_state(reg, 0)
        assert int(b.peer_ids[-1]) == 0     # moved to the end
        d = make_delta(a, b, base_version=1, new_version=2,
                       include_heartbeats=True)
        assert not d.is_full
        assert_state_equal(apply_delta(a, d), b)

    def test_boot_from_empty(self, gcfg):
        reg = self._registry(gcfg)
        b = registry_shard_state(reg, 0)
        d = make_delta(empty_state(), b, base_version=-1, new_version=1,
                       include_heartbeats=True)
        assert_state_equal(apply_delta(empty_state(), d), b)


# ---------------------------------------------------------------------------
# Seeker parity: bit-identical plans vs the anchor-composed snapshot
# ---------------------------------------------------------------------------


def _mutate_registry(reg, now):
    reg.apply_report(ExecReport(True, [0, 13, 26],
                                [HopReport(p, 40.0, True)
                                 for p in (0, 13, 26)]))
    reg.apply_report(ExecReport(False, [5], [HopReport(5, 300.0, False)],
                                failed_peer=5))
    reg.set_trust(9, 0.33)
    reg.deregister(17)
    reg.register(300, 0, 3, now=now, profile="golden")
    reg.heartbeat(300, now)


class TestSeekerParity:
    @pytest.mark.parametrize("shards", [1, 4, 16])
    def test_fully_synced_plans_bit_identical(self, gcfg, shards):
        reg = populate(make_registry(gcfg, shards=shards))
        _, (seeker,), sched = make_sync_plane(reg, gcfg, now=0.0)
        ta, ts = reg.snapshot(0.5), seeker.materialize(0.5)
        assert_tables_equal(ta, ts)
        pa = RoutePlanner(L, k_best=4)
        ps = RoutePlanner(L, k_best=4)
        _, plan_a = plan_route(ta, L, gcfg, tau=0.6, planner=pa)
        _, plan_s = plan_route(ts, L, gcfg, tau=0.6, planner=ps)
        assert plan_a.feasible
        assert plan_a.chain_rows == plan_s.chain_rows
        assert plan_a.costs == plan_s.costs

    @pytest.mark.parametrize("shards", [1, 4, 16])
    def test_parity_survives_incremental_sync(self, gcfg, shards):
        """Deltas (not just boot full-syncs) reproduce the anchor table."""
        reg = populate(make_registry(gcfg, shards=shards))
        _, (seeker,), sched = make_sync_plane(reg, gcfg, now=0.0)
        now = 0.0
        for step in range(3):
            _mutate_registry(reg, now) if step == 0 else \
                reg.set_trust(2 + step, 0.4 + 0.1 * step)
            for _ in range(16):   # fanout-capped: may need several rounds
                now += gcfg.gossip_period_s
                reg.heartbeat_all([p for p in range(48) if p != 17], now)
                reg.heartbeat(300, now)
                sched.tick(now)
                if sched.converged(seeker, now, check_table=False):
                    break
            assert sched.converged(seeker, now)
            assert_tables_equal(reg.snapshot(now), seeker.materialize(now))
        assert sched.stats.deltas > 0   # really exercised the delta path

    def test_window_router_parity(self, gcfg):
        """BatchRouter windows routed from a synced seeker table are
        bit-identical to windows routed from the anchor's snapshot."""
        reg = populate(make_registry(gcfg, shards=4))
        _, (seeker,), sched = make_sync_plane(reg, gcfg, now=0.0)
        ta, ts = reg.snapshot(0.5), seeker.materialize(0.5)
        taus = [0.55, 0.7, 0.55, 0.8, 0.0]
        ra = BatchRouter(planner=RoutePlanner(L, k_best=4), cfg=gcfg,
                         total_layers=L)
        rs = BatchRouter(planner=RoutePlanner(L, k_best=4), cfg=gcfg,
                         total_layers=L)
        for rid, tau in enumerate(taus):
            ra.submit(rid, tau)
            rs.submit(rid, tau)
        plans_a = ra.route_window(ta)
        plans_s = rs.route_window(ts)
        assert plans_a.keys() == plans_s.keys()
        for rid in plans_a:
            assert plans_a[rid].chain_rows == plans_s[rid].chain_rows
            assert plans_a[rid].costs == plans_s[rid].costs

    def test_seeker_generations_keep_caches_warm(self, gcfg):
        """Unchanged mirrors hand back the identical table object, and
        the planner's plan cache hits across windows (the zero-copy
        contract downstream caches key on)."""
        reg = populate(make_registry(gcfg, shards=4))
        _, (seeker,), sched = make_sync_plane(reg, gcfg, now=0.0)
        t1 = seeker.materialize(0.5)
        t2 = seeker.materialize(1.0)
        assert t1 is t2
        planner = RoutePlanner(L, k_best=4)
        plan_route(t1, L, gcfg, tau=0.6, planner=planner)
        plan_route(t2, L, gcfg, tau=0.6, planner=planner)
        assert planner.stats["plan_hits"] == 1
        # clean gossip rounds must not invalidate anything either
        sched.tick(2.0)
        t3 = seeker.materialize(2.5)
        assert t3 is t1


# ---------------------------------------------------------------------------
# Version gating: duplicates idempotent, gaps rejected
# ---------------------------------------------------------------------------


class TestVersionGating:
    def _plane(self, gcfg):
        reg = populate(ShardedAnchorRegistry(gcfg, n_shards=2))
        pub, (seeker,), sched = make_sync_plane(reg, gcfg, now=0.0)
        # a peer homed on shard 0, so shard-0 pulls see its mutations
        pid0 = next(p for p in reg.peers if reg.owner_of(p) == 0)
        return reg, pub, seeker, sched, pid0

    def test_duplicate_apply_is_idempotent(self, gcfg):
        reg, pub, seeker, sched, pid0 = self._plane(gcfg)
        have = seeker.version_vector[0]
        reg.set_trust(pid0, 0.5)
        d = pub.pull(0, have)
        assert seeker.apply(d, 1.0) == APPLIED
        state = seeker._states[0]
        assert seeker.apply(d, 2.0) == DUPLICATE
        assert seeker._states[0] is state          # untouched
        assert seeker.version_vector == registry_version_vector(reg)

    def test_out_of_order_older_delta_is_duplicate(self, gcfg):
        reg, pub, seeker, sched, pid0 = self._plane(gcfg)
        v0 = seeker.version_vector[0]
        reg.set_trust(pid0, 0.5)
        d1 = pub.pull(0, v0)
        reg.set_trust(pid0, 0.7)
        d2 = pub.pull(0, d1.new_version)
        assert seeker.apply(d1, 1.0) == APPLIED
        assert seeker.apply(d2, 1.0) == APPLIED
        trust = seeker._states[0].trust.copy()
        assert seeker.apply(d1, 2.0) == DUPLICATE   # stale replay
        assert np.array_equal(seeker._states[0].trust, trust)

    def test_version_gap_raises(self, gcfg):
        reg, pub, seeker, sched, pid0 = self._plane(gcfg)
        v0 = seeker.version_vector[0]
        reg.set_trust(pid0, 0.5)
        d1 = pub.pull(0, v0)
        reg.set_trust(pid0, 0.7)
        d2 = pub.pull(0, d1.new_version)
        with pytest.raises(DeltaGapError):
            seeker.apply(d2, 1.0)                   # d1 never arrived
        assert seeker.stats.gaps == 1
        # anti-entropy repairs the gap
        seeker.apply(pub.full(0), 1.0)
        assert seeker.version_vector[0] == \
            registry_version_vector(reg)[0]

    def test_same_version_full_sync_refreshes_liveness(self, gcfg):
        """Anti-entropy against a quiescent shard (version unchanged,
        heartbeats moved) must adopt the fresh liveness column and reset
        the staleness clocks — not bounce as a duplicate. Regression:
        a healed seeker used to reject these ships and mark every live
        peer TTL-dead on its next materialize."""
        cfg = GTRACConfig(gossip_hb_refresh_frac=0.0)
        reg = populate(ShardedAnchorRegistry(cfg, n_shards=2))
        pub, (seeker,), sched = make_sync_plane(reg, cfg, now=0.0)
        now = 2.0 * cfg.node_ttl_s          # way past the boot TTL
        reg.heartbeat_all(range(48), now)   # peers alive at the anchor
        assert seeker.apply(pub.full(0), now) == APPLIED
        assert seeker.apply(pub.full(1), now) == APPLIED
        assert np.all(seeker.staleness(now) == 0.0)
        ta, ts = reg.snapshot(now), seeker.materialize(now)
        assert ta.alive.all() and ts.alive.all()
        assert_tables_equal(ta, ts)

    def test_full_snapshot_applies_on_any_base(self, gcfg):
        reg, pub, seeker, sched, pid0 = self._plane(gcfg)
        rng = np.random.default_rng(0)
        for _ in range(4):
            reg.set_trust(pid0, float(rng.uniform()))
        assert seeker.apply(pub.full(0), 1.0) == APPLIED
        assert sched.converged(seeker, 1.0, check_table=False)


# ---------------------------------------------------------------------------
# Scheduler: fanout cap, clean rounds, anti-entropy after history loss
# ---------------------------------------------------------------------------


class TestScheduler:
    def test_fanout_caps_pulls_per_round(self, gcfg):
        reg = populate(ShardedAnchorRegistry(gcfg, n_shards=8), n=64)
        pub, (seeker,), sched = make_sync_plane(reg, gcfg, now=0.0)
        sched.fanout = 2
        for pid in range(64):          # dirty every shard
            reg.set_trust(pid, 0.6)
        shipped0 = sched.stats.deltas + sched.stats.full_syncs
        sched.tick(1.0)
        assert (sched.stats.deltas + sched.stats.full_syncs
                - shipped0) <= 2
        assert sched.stats.deferred > 0
        for r in range(8):             # the rest drain over later rounds
            if sched.converged(seeker, 1.0 + r, check_table=False):
                break
            sched.tick(1.0 + r)
        assert sched.converged(seeker, 10.0)

    def test_clean_round_ships_nothing(self, gcfg):
        reg = populate(ShardedAnchorRegistry(gcfg, n_shards=4))
        pub, (seeker,), sched = make_sync_plane(reg, gcfg, now=0.0)
        d0, f0 = sched.stats.deltas, sched.stats.full_syncs
        sched.tick(1.0)
        assert (sched.stats.deltas, sched.stats.full_syncs) == (d0, f0)
        # a clean observation still refreshes the staleness clock
        assert seeker.staleness(1.0).max() == 0.0

    def test_history_eviction_forces_anti_entropy(self, gcfg):
        """A seeker partitioned past the publisher's history depth gets a
        full shard snapshot, not a broken delta chain."""
        cfg = GTRACConfig(gossip_history=1)
        reg = populate(ShardedAnchorRegistry(cfg, n_shards=2))
        pub, (seeker,), sched = make_sync_plane(reg, cfg, now=0.0)
        pid0 = next(p for p in reg.peers if reg.owner_of(p) == 0)
        sched.partition(seeker, [0])
        for i in range(4):             # several version bumps while cut off
            reg.set_trust(pid0, 0.4 + 0.1 * i)
            pub.shard_state(0)         # each export evicts the previous
        sched.heal(seeker, [0])
        full0 = sched.stats.full_syncs
        sched.tick(1.0)
        assert sched.stats.full_syncs > full0
        assert sched.converged(seeker, 1.0)

    def test_maybe_tick_respects_period(self, gcfg):
        reg = populate(ShardedAnchorRegistry(gcfg, n_shards=2))
        pub, (seeker,), sched = make_sync_plane(reg, gcfg, now=0.0)
        assert sched.maybe_tick(0.0)
        assert not sched.maybe_tick(gcfg.gossip_period_s * 0.5)
        assert sched.maybe_tick(gcfg.gossip_period_s * 1.5)


# ---------------------------------------------------------------------------
# Staleness-bounded routing
# ---------------------------------------------------------------------------


class TestStalenessRouting:
    def _plane(self, cfg):
        reg = populate(ShardedAnchorRegistry(cfg, n_shards=4))
        return reg, *make_sync_plane(reg, cfg, now=0.0)[1:]

    def test_fresh_cache_routes_on_the_base_table(self):
        cfg = GTRACConfig(gossip_stale_margin=0.05)
        reg = populate(ShardedAnchorRegistry(cfg, n_shards=4))
        _, (seeker,), sched = make_sync_plane(reg, cfg, now=0.0)
        assert seeker.routing_view(0.5) is seeker.materialize(0.5)

    def test_stale_shards_lose_routing_trust(self):
        cfg = GTRACConfig(gossip_stale_margin=0.05,
                          gossip_stale_margin_max=0.3)
        reg = populate(ShardedAnchorRegistry(cfg, n_shards=4))
        _, (seeker,), sched = make_sync_plane(reg, cfg, now=0.0)
        sched.partition(seeker, [0, 1])
        now = 0.0
        for _ in range(4):
            now += cfg.gossip_period_s
            reg.heartbeat_all(range(48), now)
            sched.tick(now)
        base = seeker.materialize(now)
        adj = seeker.routing_view(now)
        assert adj is not base
        assert adj.source_id != base.source_id
        rounds = seeker.staleness_rounds(now)
        assert rounds[[0, 1]].min() >= 4
        assert np.all(rounds[[2, 3]] <= 1)
        stale_rows = np.isin(base.peer_ids,
                             [pid for pid in range(48)
                              if reg.owner_of(pid) in (0, 1)])
        dock = base.trust - adj.trust
        expected = np.minimum(0.05 * rounds.max(), 0.3)
        assert np.allclose(dock[stale_rows], expected)
        assert np.all(dock[~stale_rows] == 0.0)   # fresh shards untouched

    def test_stale_trust_discounts_toward_init(self):
        """gossip_stale_decay mirrors the anchor sweep's decay law on the
        seeker side: unconfirmed trust drifts back to the prior."""
        cfg = GTRACConfig(init_trust=0.8, gossip_stale_decay=0.1)
        reg = populate(ShardedAnchorRegistry(cfg, n_shards=2))
        _, (seeker,), sched = make_sync_plane(reg, cfg, now=0.0)
        sched.partition(seeker)
        now = 20.0
        base = seeker.materialize(now)
        adj = seeker.routing_view(now)
        f = np.exp(-0.1 * seeker.staleness(now))
        expected = 0.8 + (base.trust - 0.8) * f[0]
        assert np.allclose(adj.trust, np.clip(expected, 0.0, 1.0))
        # closer to the prior than the raw estimate everywhere
        assert np.all(np.abs(adj.trust - 0.8)
                      <= np.abs(base.trust - 0.8) + 1e-12)

    def test_stale_routing_is_conservative(self):
        """A peer riding just above the trust floor on a stale shard must
        fall out of the feasible set — the partitioned seeker demands a
        margin it cannot confirm."""
        cfg = GTRACConfig(gossip_stale_margin=0.05,
                          gossip_stale_margin_max=0.5)
        reg = populate(ShardedAnchorRegistry(cfg, n_shards=1))
        _, (seeker,), sched = make_sync_plane(reg, cfg, now=0.0)
        tau = 0.6
        base = seeker.materialize(0.0)
        fresh_mask = base.alive & (base.trust >= tau)
        assert fresh_mask.sum() > 0
        sched.partition(seeker)
        now = 10 * cfg.gossip_period_s
        adj = seeker.routing_view(now)
        stale_mask = adj.alive & (adj.trust >= tau)
        assert stale_mask.sum() < fresh_mask.sum()
        assert not np.any(stale_mask & ~fresh_mask)   # never less strict

    def test_routing_view_cached_per_round(self):
        cfg = GTRACConfig(gossip_stale_margin=0.05)
        reg = populate(ShardedAnchorRegistry(cfg, n_shards=2))
        _, (seeker,), sched = make_sync_plane(reg, cfg, now=0.0)
        sched.partition(seeker)
        t1 = seeker.routing_view(3.0)
        t2 = seeker.routing_view(3.5)    # same stale-round vector
        assert t1 is t2
        t3 = seeker.routing_view(3.0 + 2 * cfg.gossip_period_s)
        assert t3 is not t1
        assert t3.version != t1.version


# ---------------------------------------------------------------------------
# Partition simulation (sim/testbed.py)
# ---------------------------------------------------------------------------


class TestPartitionRecovery:
    def test_partition_heal_convergence(self, gcfg):
        cfg = GTRACConfig(gossip_fanout=2, gossip_stale_margin=0.02)
        bed = build_scaling_testbed(96, cfg=cfg, seed=3, shards=4)
        _, (seeker,), sched = make_sync_plane(bed.anchor, cfg, now=bed.now)
        pids = sorted(bed.peers)

        def churn(bed):
            chain = [int(p) for p in pids[:3]]
            bed.anchor.apply_report(ExecReport(
                True, chain, [HopReport(p, 60.0, True) for p in chain]))

        stats = simulate_partition(bed, sched, seeker, [0, 1],
                                   partition_windows=4, window_s=2.0,
                                   mutate=churn)
        assert stats.converged
        assert stats.rounds_to_convergence >= 0
        assert stats.max_stale_rounds >= 3     # it really went stale
        ta = bed.anchor.snapshot(bed.now)
        assert_tables_equal(ta, seeker.materialize(bed.now))
        # post-heal the routing view is the base table again (no margin)
        assert seeker.routing_view(bed.now) is seeker.materialize(bed.now)

    def test_staleness_grows_only_on_blocked_shards(self, gcfg):
        reg = populate(ShardedAnchorRegistry(gcfg, n_shards=4))
        _, (seeker,), sched = make_sync_plane(reg, gcfg, now=0.0)
        sched.partition(seeker, [2])
        now = 0.0
        for _ in range(3):
            now += gcfg.gossip_period_s
            sched.tick(now)
        ages = seeker.staleness(now)
        assert ages[2] == pytest.approx(3 * gcfg.gossip_period_s)
        assert np.all(ages[[0, 1, 3]] == 0.0)


# ---------------------------------------------------------------------------
# Property tests: random mutation scripts (hypothesis)
# ---------------------------------------------------------------------------

N_PROP_PEERS = 24


def _apply_op(reg, op, now, next_pid):
    """One scripted registry mutation. op = (kind, a, b) small ints."""
    kind, a, b = op[0] % 6, op[1], op[2]
    pids = list(reg.peers)
    if kind == 0:                                   # register fresh
        pid = next_pid[0]
        next_pid[0] += 1
        reg.register(pid, (a % 4) * 3, (a % 4) * 3 + 3, now=now,
                     profile="golden", trust=0.5 + (b % 50) / 100.0)
        reg.heartbeat(pid, now)
    elif kind == 1 and pids:                        # deregister
        reg.deregister(pids[a % len(pids)])
    elif kind == 2 and pids:                        # out-of-band trust write
        reg.set_trust(pids[a % len(pids)], (b % 100) / 100.0)
    elif kind == 3 and pids:                        # execution report
        chain = [pids[a % len(pids)], pids[b % len(pids)]]
        ok = (a + b) % 2 == 0
        reg.apply_report(ExecReport(
            ok, chain if ok else [],
            [HopReport(p, 20.0 + b, True) for p in chain],
            failed_peer=None if ok else chain[0]))
    elif kind == 4 and pids:                        # heartbeat
        reg.heartbeat(pids[a % len(pids)], now)
    else:                                           # decaying sweep
        reg.sweep(now, decay_rate=0.05)


def _sync_round(reg, pub, seeker, now, prev_deltas):
    """Delta-sync every dirty shard; returns the deltas shipped."""
    vv = registry_version_vector(reg)
    shipped = []
    for s in range(pub.n_shards):
        have = seeker.version_vector[s]
        if vv[s] == have:
            continue
        d = pub.pull(s, have)
        assert seeker.apply(d, now) == APPLIED
        shipped.append(d)
        # replay is idempotent: non-full deltas bounce as duplicates; a
        # full snapshot at the mirrored version is accepted as a
        # liveness refresh but leaves the state object untouched (its
        # heartbeat column is identical)
        st_before = seeker._states[s]
        assert seeker.apply(d, now) == \
            (APPLIED if d.is_full else DUPLICATE)
        assert seeker._states[s] is st_before
    # out-of-order replay of an older round's delta is rejected or
    # idempotent: never silently merged (full snapshots AT the mirrored
    # version count as liveness refreshes, not merges)
    for d in prev_deltas:
        cur = seeker.version_vector[d.shard]
        if d.is_full and d.new_version == cur:
            assert seeker.apply(d, now) == APPLIED
        elif d.new_version <= cur:
            assert seeker.apply(d, now) == DUPLICATE
        else:
            with pytest.raises(DeltaGapError):
                seeker.apply(d, now)
    return shipped


def _run_mutation_script(script, n_shards=4):
    """Drive a sharded registry through a mutation script, delta-syncing
    after every round; per-shard mirrors must equal the anchor's state
    byte-for-byte at every round boundary (deltas compose across
    rounds), and replays/gaps must be handled."""
    cfg = GTRACConfig(ttl_expire_factor=4.0)
    reg = populate(ShardedAnchorRegistry(cfg, n_shards=n_shards),
                   n=N_PROP_PEERS, seed=2)
    pub = GossipPublisher(reg, cfg)
    seeker = SeekerCache(cfg, n_shards, now=0.0)
    for s in range(n_shards):
        seeker.apply(pub.full(s), 0.0)
    next_pid = [1000]
    now = 0.0
    prev = []
    for rnd in script:
        now += 1.0
        for op in rnd:
            _apply_op(reg, op, now, next_pid)
        prev = _sync_round(reg, pub, seeker, now, prev)
        for s in range(n_shards):
            a = registry_shard_state(reg, s)
            b = seeker._states[s]
            # exact mirror modulo heartbeat drift (hb is not a diffed
            # column; see sync/delta.py)
            assert np.array_equal(a.peer_ids, b.peer_ids)
            assert np.array_equal(a.trust, b.trust)
            assert np.array_equal(a.latency_ms, b.latency_ms)
            assert np.array_equal(a.seq, b.seq)
            assert np.array_equal(a.successes, b.successes)
            assert np.array_equal(a.failures, b.failures)
    assert seeker.version_vector == registry_version_vector(reg)


_op = st.tuples(st.integers(0, 11), st.integers(0, 63), st.integers(0, 99))


class TestDeltaProperties:
    @given(script=st.lists(st.lists(_op, max_size=6), max_size=5))
    @settings(max_examples=30, deadline=None)
    def test_random_mutation_scripts(self, script):
        _run_mutation_script(script)

    def test_fixed_random_scripts(self):
        """Deterministic twin of the property test (runs when hypothesis
        is unavailable): a few seeded random scripts through the same
        harness."""
        rng = np.random.default_rng(7)
        for _ in range(4):
            script = [[(int(rng.integers(12)), int(rng.integers(64)),
                        int(rng.integers(100)))
                       for _ in range(int(rng.integers(1, 7)))]
                      for _ in range(int(rng.integers(1, 6)))]
            _run_mutation_script(script)
