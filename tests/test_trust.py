"""Trust protocol tests: EWMA, asymmetric updates, liveness, gossip
staleness, and the jitted JAX twin."""
import jax.numpy as jnp
import pytest

from repro.configs.base import GTRACConfig
from repro.core import AnchorRegistry, SeekerCache
from repro.core.trust import effective_cost, ewma_latency, jax_apply_report, penalize, reward
from repro.core.types import ExecReport, HopReport

from _hyp import given, settings, st


class TestRules:
    def test_ewma(self, gcfg):
        assert ewma_latency(100.0, 200.0, 0.3) == pytest.approx(130.0)

    def test_effective_cost_penalises_unreliable(self, gcfg):
        fast_risky = effective_cost(1.0, 0.7, gcfg.request_timeout_ms)
        slow_safe = effective_cost(300.0, 1.0, gcfg.request_timeout_ms)
        assert fast_risky > slow_safe  # the honey-pot defence, Eq. (4)

    def test_reward_penalty_caps(self, gcfg):
        assert reward(0.99, gcfg) == gcfg.max_trust
        assert penalize(0.1, gcfg) == gcfg.min_trust

    @given(r=st.floats(0.0, 1.0))
    @settings(max_examples=100, deadline=None)
    def test_updates_stay_in_unit_interval(self, r):
        cfg = GTRACConfig()
        assert 0.0 <= reward(r, cfg) <= 1.0
        assert 0.0 <= penalize(r, cfg) <= 1.0


class TestRegistry:
    def test_targeted_attribution(self, gcfg):
        """Success rewards ALL chain peers; failure penalises ONLY the
        failing hop (§IV-C)."""
        a = AnchorRegistry(gcfg)
        for pid in range(3):
            a.register(pid, pid, pid + 1, now=0.0)
        t0 = {pid: a.peers[pid].trust for pid in range(3)}
        a.apply_report(ExecReport(True, [0, 1, 2],
                                  [HopReport(p, 100.0, True)
                                   for p in range(3)]))
        for pid in range(3):
            assert a.peers[pid].trust == pytest.approx(
                min(1.0, t0[pid] + gcfg.trust_reward))
        t1 = {pid: a.peers[pid].trust for pid in range(3)}
        a.apply_report(ExecReport(False, [0, 1, 2],
                                  [HopReport(1, 100.0, False)],
                                  failed_peer=1))
        assert a.peers[0].trust == t1[0]
        assert a.peers[2].trust == t1[2]
        assert a.peers[1].trust == pytest.approx(t1[1] - gcfg.trust_penalty)

    def test_failure_isolates_below_floor(self, gcfg):
        a = AnchorRegistry(gcfg)
        a.register(0, 0, 3, now=0.0)
        a.apply_report(ExecReport(False, [0], [HopReport(0, 1.0, False)],
                                  failed_peer=0))
        assert a.peers[0].trust < gcfg.trust_floor  # one strike isolates

    def test_liveness_ttl(self, gcfg):
        a = AnchorRegistry(gcfg)
        a.register(0, 0, 3, now=0.0)
        a.register(1, 0, 3, now=0.0)
        a.heartbeat(0, 100.0)
        a.heartbeat(1, 100.0 - gcfg.node_ttl_s - 1)
        t = a.snapshot(100.0)
        assert bool(t.alive[t.index_of(0)])
        assert not bool(t.alive[t.index_of(1)])

    def test_latency_ewma_only_on_executed_hops(self, gcfg):
        a = AnchorRegistry(gcfg)
        a.register(0, 0, 3, now=0.0, latency_ms=100.0)
        a.apply_report(ExecReport(True, [0], [HopReport(0, 200.0, True)]))
        assert a.peers[0].latency_est_ms == pytest.approx(
            (1 - gcfg.ewma_beta) * 100 + gcfg.ewma_beta * 200)


class TestGossip:
    def test_cache_is_stale_between_syncs(self, gcfg):
        a = AnchorRegistry(gcfg)
        a.register(0, 0, 3, now=0.0)
        cache = SeekerCache(a, gcfg, now=0.0)
        # via the registry API: direct record writes bypass the versioned
        # snapshot cache (see registry.py snapshot-versioning contract)
        a.set_trust(0, 0.123)
        # before T_gossip: stale view unchanged
        assert not cache.maybe_sync(gcfg.gossip_period_s / 2)
        assert cache.view().trust[0] != pytest.approx(0.123)
        # after T_gossip: refreshed
        assert cache.maybe_sync(gcfg.gossip_period_s + 0.01)
        assert cache.view().trust[0] == pytest.approx(0.123)

    def test_routing_never_blocks_on_anchor(self, gcfg):
        """The cached view is routable even if the anchor has moved on."""
        a = AnchorRegistry(gcfg)
        a.register(0, 0, 3, now=0.0)
        a.heartbeat(0, 0.0)
        cache = SeekerCache(a, gcfg, now=0.0)
        a.deregister(0)                      # anchor state changed
        t = cache.view()                     # seeker still routes on cache
        assert len(t) == 1


class TestJaxTwin:
    def test_matches_python_rules(self, gcfg):
        trust = jnp.array([0.9, 0.8, 0.7, 0.6])
        lat = jnp.array([100.0, 200.0, 300.0, 400.0])
        chain = jnp.array([True, True, False, False])
        failed = jnp.array([False, False, False, False])
        obs = jnp.array([150.0, 250.0, 0.0, 0.0])
        nt, nl = jax_apply_report(trust, lat, chain, failed, obs,
                                  jnp.bool_(True), gcfg)
        assert float(nt[0]) == pytest.approx(reward(0.9, gcfg))
        assert float(nt[2]) == pytest.approx(0.7)
        assert float(nl[0]) == pytest.approx(ewma_latency(100, 150,
                                                          gcfg.ewma_beta))
        assert float(nl[2]) == pytest.approx(300.0)
        # failure path
        failed = jnp.array([False, True, False, False])
        nt2, _ = jax_apply_report(trust, lat, chain, failed, obs,
                                  jnp.bool_(False), gcfg)
        assert float(nt2[1]) == pytest.approx(penalize(0.8, gcfg))
        assert float(nt2[0]) == pytest.approx(0.9)
